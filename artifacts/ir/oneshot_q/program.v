// @meta name oneshot_q
// @meta states 1573
// @meta instrs 1075
// @io input 0 mem r0 dtype i32 width 8 shape 1x16000
// @io output 0 mem r1317 dtype i32 width 11 shape 1x10
// @io output 1 mem r1207 dtype i32 width 8 shape 1x30
// @io output 2 mem r1169 dtype i32 width 25 shape 1x30
// @rom rom0_c file rom/rom0_c.mem words 80
// @rom rom1_c file rom/rom1_c.mem words 6
// @rom rom2_c file rom/rom2_c.mem words 30
// @rom rom3_c file rom/rom3_c.mem words 30
// @rom rom4_c file rom/rom4_c.mem words 30
// @rom rom5_c file rom/rom5_c.mem words 300
// @rom rom6_c file rom/rom6_c.mem words 300
// @rom rom7_c file rom/rom7_c.mem words 10
// @rom rom8_lit file rom/rom8_lit.mem words 1
// @rom rom9_lit file rom/rom9_lit.mem words 1
// @rom rom10_lit file rom/rom10_lit.mem words 1
// @rom rom11_lit file rom/rom11_lit.mem words 1
// @rom rom12_lit file rom/rom12_lit.mem words 1
// @rom rom13_lit file rom/rom13_lit.mem words 1
// @rom rom14_lit file rom/rom14_lit.mem words 1
// @rom rom15_lit file rom/rom15_lit.mem words 1
// @rom rom16_lit file rom/rom16_lit.mem words 1
// @rom rom17_lit file rom/rom17_lit.mem words 1
// @rom rom18_lit file rom/rom18_lit.mem words 1
// @rom rom19_lit file rom/rom19_lit.mem words 1
// @rom rom20_lit file rom/rom20_lit.mem words 1
// @rom rom21_lit file rom/rom21_lit.mem words 1
// @rom rom22_lit file rom/rom22_lit.mem words 1
// @rom rom23_lit file rom/rom23_lit.mem words 1
// @rom rom24_lit file rom/rom24_lit.mem words 1
// @rom rom25_lit file rom/rom25_lit.mem words 1
// @rom rom26_lit file rom/rom26_lit.mem words 1
// @rom rom27_lit file rom/rom27_lit.mem words 1
// @rom rom28_lit file rom/rom28_lit.mem words 1
// @rom rom29_lit file rom/rom29_lit.mem words 1
// @rom rom30_lit file rom/rom30_lit.mem words 1
// @rom rom31_lit file rom/rom31_lit.mem words 1
// @rom rom32_lit file rom/rom32_lit.mem words 1
// @rom rom33_lit file rom/rom33_lit.mem words 1
// @rom rom34_lit file rom/rom34_lit.mem words 1
// @trace state 1 instr 0 op shl dests r10
// @trace state 2 instr 1 op mov dests r11
// @trace state 3 instr 2 op rev dests r12
// @trace state 4 instr 3 op reshape dests r13
// @trace state 5 instr 4 op convert dests r15
// @trace state 7 instr 5 op pad dests r16
// @trace state 8 instr 6 op convert dests r17
// @trace state 10 instr 7 op pad dests r18
// @trace state 11 instr 8 op iota dests r19
// @trace state 12 instr 9 op broadcast dests r20
// @trace state 13 instr 10 op iota dests r21
// @trace state 14 instr 11 op broadcast dests r22
// @trace state 15 instr 12 op add dests r23
// @trace state 16 instr 13 op iota dests r24
// @trace state 17 instr 14 op shl dests r25
// @trace state 24 instr 16 op add dests r30
// @trace state 25 instr 17 op select_n dests r32
// @trace state 26 instr 18 op lt dests r33
// @trace state 27 instr 19 op add dests r35
// @trace state 28 instr 20 op select_n dests r36
// @trace state 29 instr 21 op dynamic_slice dests r37
// @trace state 30 instr 22 op lt dests r38
// @trace state 31 instr 23 op add dests r40
// @trace state 32 instr 24 op select_n dests r41
// @trace state 33 instr 25 op broadcast dests r42
// @trace state 34 instr 26 op gather dests r43
// @trace state 35 instr 27 op broadcast dests r44
// @trace state 36 instr 28 op add dests r45
// @trace state 37 instr 29 op convert dests r48
// @trace state 38 instr 30 op max dests r49
// @trace state 39 instr 31 op convert dests r50
// @trace state 40 instr 32 op min dests r51
// @trace state 41 instr 33 op sub dests r52
// @trace state 42 instr 34 op convert dests r53
// @trace state 43 instr 35 op max dests r54
// @trace state 44 instr 36 op convert dests r55
// @trace state 45 instr 37 op min dests r56
// @trace state 46 instr 38 op abs dests r57
// @trace state 48 instr 39 op reduce_max dests r58
// @trace state 49 instr 40 op sub dests r60
// @trace state 57 instr 42 op add dests r66
// @trace state 58 instr 43 op add dests r67
// @trace state 59 instr 44 op shra dests r68
// @trace state 60 instr 45 op broadcast dests r69
// @trace state 61 instr 46 op sub dests r70
// @trace state 62 instr 47 op max dests r71
// @trace state 64 instr 48 op reduce_sum dests r72
// @trace state 65 instr 49 op neg dests r73
// @trace state 66 instr 50 op broadcast dests r74
// @trace state 67 instr 51 op sub dests r75
// @trace state 68 instr 52 op max dests r76
// @trace state 70 instr 53 op reduce_sum dests r77
// @trace state 71 instr 54 op add dests r78
// @trace state 72 instr 55 op gt dests r79
// @trace state 73 instr 56 op select_n dests r80
// @trace state 74 instr 57 op select_n dests r81
// @trace state 81 instr 41 op loop dests r82 r83 r84
// @trace state 82 instr 58 op abs dests r85
// @trace state 84 instr 59 op reduce_max dests r86
// @trace state 85 instr 60 op sub dests r87
// @trace state 93 instr 62 op add dests r93
// @trace state 94 instr 63 op add dests r94
// @trace state 95 instr 64 op shra dests r95
// @trace state 96 instr 65 op broadcast dests r96
// @trace state 97 instr 66 op sub dests r97
// @trace state 98 instr 67 op max dests r98
// @trace state 100 instr 68 op reduce_sum dests r99
// @trace state 101 instr 69 op neg dests r100
// @trace state 102 instr 70 op broadcast dests r101
// @trace state 103 instr 71 op sub dests r102
// @trace state 104 instr 72 op max dests r103
// @trace state 106 instr 73 op reduce_sum dests r104
// @trace state 107 instr 74 op add dests r105
// @trace state 108 instr 75 op gt dests r106
// @trace state 109 instr 76 op select_n dests r107
// @trace state 110 instr 77 op select_n dests r108
// @trace state 117 instr 61 op loop dests r109 r110 r111
// @trace state 118 instr 78 op sub dests r112
// @trace state 121 instr 15 op loop dests r113
// @trace state 122 instr 79 op transpose dests r114
// @trace state 123 instr 80 op reshape dests r115
// @trace state 124 instr 81 op slice dests r116
// @trace state 125 instr 82 op transpose dests r117
// @trace state 126 instr 83 op max dests r118
// @trace state 128 instr 84 op reduce_sum dests r119
// @trace state 129 instr 85 op shl dests r120
// @trace state 130 instr 86 op shl dests r121
// @trace state 131 instr 87 op mov dests r122
// @trace state 132 instr 88 op rev dests r123
// @trace state 133 instr 89 op reshape dests r124
// @trace state 134 instr 90 op convert dests r125
// @trace state 136 instr 91 op pad dests r126
// @trace state 137 instr 92 op convert dests r127
// @trace state 139 instr 93 op pad dests r128
// @trace state 140 instr 94 op iota dests r129
// @trace state 141 instr 95 op broadcast dests r130
// @trace state 142 instr 96 op iota dests r131
// @trace state 143 instr 97 op broadcast dests r132
// @trace state 144 instr 98 op add dests r133
// @trace state 145 instr 99 op iota dests r134
// @trace state 146 instr 100 op shl dests r135
// @trace state 153 instr 102 op add dests r140
// @trace state 154 instr 103 op select_n dests r141
// @trace state 155 instr 104 op lt dests r142
// @trace state 156 instr 105 op add dests r144
// @trace state 157 instr 106 op select_n dests r145
// @trace state 158 instr 107 op dynamic_slice dests r146
// @trace state 159 instr 108 op lt dests r147
// @trace state 160 instr 109 op add dests r149
// @trace state 161 instr 110 op select_n dests r150
// @trace state 162 instr 111 op broadcast dests r151
// @trace state 163 instr 112 op gather dests r152
// @trace state 164 instr 113 op broadcast dests r153
// @trace state 165 instr 114 op add dests r154
// @trace state 166 instr 115 op convert dests r155
// @trace state 167 instr 116 op max dests r156
// @trace state 168 instr 117 op convert dests r157
// @trace state 169 instr 118 op min dests r158
// @trace state 170 instr 119 op sub dests r159
// @trace state 171 instr 120 op convert dests r160
// @trace state 172 instr 121 op max dests r161
// @trace state 173 instr 122 op convert dests r162
// @trace state 174 instr 123 op min dests r163
// @trace state 175 instr 124 op abs dests r164
// @trace state 177 instr 125 op reduce_max dests r165
// @trace state 178 instr 126 op sub dests r166
// @trace state 186 instr 128 op add dests r172
// @trace state 187 instr 129 op add dests r173
// @trace state 188 instr 130 op shra dests r174
// @trace state 189 instr 131 op broadcast dests r175
// @trace state 190 instr 132 op sub dests r176
// @trace state 191 instr 133 op max dests r177
// @trace state 193 instr 134 op reduce_sum dests r178
// @trace state 194 instr 135 op neg dests r179
// @trace state 195 instr 136 op broadcast dests r180
// @trace state 196 instr 137 op sub dests r181
// @trace state 197 instr 138 op max dests r182
// @trace state 199 instr 139 op reduce_sum dests r183
// @trace state 200 instr 140 op add dests r184
// @trace state 201 instr 141 op gt dests r185
// @trace state 202 instr 142 op select_n dests r186
// @trace state 203 instr 143 op select_n dests r187
// @trace state 210 instr 127 op loop dests r188 r189 r190
// @trace state 211 instr 144 op abs dests r191
// @trace state 213 instr 145 op reduce_max dests r192
// @trace state 214 instr 146 op sub dests r193
// @trace state 222 instr 148 op add dests r199
// @trace state 223 instr 149 op add dests r200
// @trace state 224 instr 150 op shra dests r201
// @trace state 225 instr 151 op broadcast dests r202
// @trace state 226 instr 152 op sub dests r203
// @trace state 227 instr 153 op max dests r204
// @trace state 229 instr 154 op reduce_sum dests r205
// @trace state 230 instr 155 op neg dests r206
// @trace state 231 instr 156 op broadcast dests r207
// @trace state 232 instr 157 op sub dests r208
// @trace state 233 instr 158 op max dests r209
// @trace state 235 instr 159 op reduce_sum dests r210
// @trace state 236 instr 160 op add dests r211
// @trace state 237 instr 161 op gt dests r212
// @trace state 238 instr 162 op select_n dests r213
// @trace state 239 instr 163 op select_n dests r214
// @trace state 246 instr 147 op loop dests r215 r216 r217
// @trace state 247 instr 164 op sub dests r218
// @trace state 250 instr 101 op loop dests r219
// @trace state 251 instr 165 op transpose dests r220
// @trace state 252 instr 166 op reshape dests r221
// @trace state 253 instr 167 op slice dests r222
// @trace state 254 instr 168 op transpose dests r223
// @trace state 255 instr 169 op slice dests r224
// @trace state 256 instr 170 op reshape dests r225
// @trace state 257 instr 171 op shra dests r226
// @trace state 258 instr 172 op convert dests r229
// @trace state 259 instr 173 op max dests r230
// @trace state 260 instr 174 op convert dests r231
// @trace state 261 instr 175 op min dests r232
// @trace state 262 instr 176 op iota dests r233
// @trace state 263 instr 177 op shl dests r234
// @trace state 264 instr 178 op add dests r235
// @trace state 265 instr 179 op broadcast dests r236
// @trace state 266 instr 180 op gather dests r237
// @trace state 267 instr 181 op shl dests r238
// @trace state 268 instr 182 op mov dests r239
// @trace state 269 instr 183 op rev dests r240
// @trace state 270 instr 184 op reshape dests r241
// @trace state 271 instr 185 op convert dests r242
// @trace state 273 instr 186 op pad dests r243
// @trace state 274 instr 187 op convert dests r244
// @trace state 276 instr 188 op pad dests r245
// @trace state 277 instr 189 op iota dests r246
// @trace state 278 instr 190 op broadcast dests r247
// @trace state 279 instr 191 op iota dests r248
// @trace state 280 instr 192 op broadcast dests r249
// @trace state 281 instr 193 op add dests r250
// @trace state 282 instr 194 op iota dests r251
// @trace state 283 instr 195 op shl dests r252
// @trace state 290 instr 197 op add dests r257
// @trace state 291 instr 198 op select_n dests r258
// @trace state 292 instr 199 op lt dests r259
// @trace state 293 instr 200 op add dests r261
// @trace state 294 instr 201 op select_n dests r262
// @trace state 295 instr 202 op dynamic_slice dests r263
// @trace state 296 instr 203 op lt dests r264
// @trace state 297 instr 204 op add dests r265
// @trace state 298 instr 205 op select_n dests r266
// @trace state 299 instr 206 op broadcast dests r267
// @trace state 300 instr 207 op gather dests r268
// @trace state 301 instr 208 op broadcast dests r269
// @trace state 302 instr 209 op add dests r270
// @trace state 303 instr 210 op convert dests r271
// @trace state 304 instr 211 op max dests r272
// @trace state 305 instr 212 op convert dests r273
// @trace state 306 instr 213 op min dests r274
// @trace state 307 instr 214 op sub dests r275
// @trace state 308 instr 215 op convert dests r276
// @trace state 309 instr 216 op max dests r277
// @trace state 310 instr 217 op convert dests r278
// @trace state 311 instr 218 op min dests r279
// @trace state 312 instr 219 op abs dests r280
// @trace state 314 instr 220 op reduce_max dests r281
// @trace state 315 instr 221 op sub dests r282
// @trace state 323 instr 223 op add dests r288
// @trace state 324 instr 224 op add dests r289
// @trace state 325 instr 225 op shra dests r290
// @trace state 326 instr 226 op broadcast dests r291
// @trace state 327 instr 227 op sub dests r292
// @trace state 328 instr 228 op max dests r293
// @trace state 330 instr 229 op reduce_sum dests r294
// @trace state 331 instr 230 op neg dests r295
// @trace state 332 instr 231 op broadcast dests r296
// @trace state 333 instr 232 op sub dests r297
// @trace state 334 instr 233 op max dests r298
// @trace state 336 instr 234 op reduce_sum dests r299
// @trace state 337 instr 235 op add dests r300
// @trace state 338 instr 236 op gt dests r301
// @trace state 339 instr 237 op select_n dests r302
// @trace state 340 instr 238 op select_n dests r303
// @trace state 347 instr 222 op loop dests r304 r305 r306
// @trace state 348 instr 239 op abs dests r307
// @trace state 350 instr 240 op reduce_max dests r308
// @trace state 351 instr 241 op sub dests r309
// @trace state 359 instr 243 op add dests r315
// @trace state 360 instr 244 op add dests r316
// @trace state 361 instr 245 op shra dests r317
// @trace state 362 instr 246 op broadcast dests r318
// @trace state 363 instr 247 op sub dests r319
// @trace state 364 instr 248 op max dests r320
// @trace state 366 instr 249 op reduce_sum dests r321
// @trace state 367 instr 250 op neg dests r322
// @trace state 368 instr 251 op broadcast dests r323
// @trace state 369 instr 252 op sub dests r324
// @trace state 370 instr 253 op max dests r325
// @trace state 372 instr 254 op reduce_sum dests r326
// @trace state 373 instr 255 op add dests r327
// @trace state 374 instr 256 op gt dests r328
// @trace state 375 instr 257 op select_n dests r329
// @trace state 376 instr 258 op select_n dests r330
// @trace state 383 instr 242 op loop dests r331 r332 r333
// @trace state 384 instr 259 op sub dests r334
// @trace state 387 instr 196 op loop dests r335
// @trace state 388 instr 260 op transpose dests r336
// @trace state 389 instr 261 op reshape dests r337
// @trace state 390 instr 262 op slice dests r338
// @trace state 391 instr 263 op transpose dests r339
// @trace state 392 instr 264 op max dests r340
// @trace state 394 instr 265 op reduce_sum dests r341
// @trace state 395 instr 266 op shl dests r342
// @trace state 396 instr 267 op shl dests r343
// @trace state 397 instr 268 op mov dests r344
// @trace state 398 instr 269 op rev dests r345
// @trace state 399 instr 270 op reshape dests r346
// @trace state 400 instr 271 op convert dests r347
// @trace state 402 instr 272 op pad dests r348
// @trace state 403 instr 273 op convert dests r349
// @trace state 405 instr 274 op pad dests r350
// @trace state 406 instr 275 op iota dests r351
// @trace state 407 instr 276 op broadcast dests r352
// @trace state 408 instr 277 op iota dests r353
// @trace state 409 instr 278 op broadcast dests r354
// @trace state 410 instr 279 op add dests r355
// @trace state 411 instr 280 op iota dests r356
// @trace state 412 instr 281 op shl dests r357
// @trace state 419 instr 283 op add dests r362
// @trace state 420 instr 284 op select_n dests r363
// @trace state 421 instr 285 op lt dests r364
// @trace state 422 instr 286 op add dests r366
// @trace state 423 instr 287 op select_n dests r367
// @trace state 424 instr 288 op dynamic_slice dests r368
// @trace state 425 instr 289 op lt dests r369
// @trace state 426 instr 290 op add dests r370
// @trace state 427 instr 291 op select_n dests r371
// @trace state 428 instr 292 op broadcast dests r372
// @trace state 429 instr 293 op gather dests r373
// @trace state 430 instr 294 op broadcast dests r374
// @trace state 431 instr 295 op add dests r375
// @trace state 432 instr 296 op convert dests r376
// @trace state 433 instr 297 op max dests r377
// @trace state 434 instr 298 op convert dests r378
// @trace state 435 instr 299 op min dests r379
// @trace state 436 instr 300 op sub dests r380
// @trace state 437 instr 301 op convert dests r381
// @trace state 438 instr 302 op max dests r382
// @trace state 439 instr 303 op convert dests r383
// @trace state 440 instr 304 op min dests r384
// @trace state 441 instr 305 op abs dests r385
// @trace state 443 instr 306 op reduce_max dests r386
// @trace state 444 instr 307 op sub dests r387
// @trace state 452 instr 309 op add dests r393
// @trace state 453 instr 310 op add dests r394
// @trace state 454 instr 311 op shra dests r395
// @trace state 455 instr 312 op broadcast dests r396
// @trace state 456 instr 313 op sub dests r397
// @trace state 457 instr 314 op max dests r398
// @trace state 459 instr 315 op reduce_sum dests r399
// @trace state 460 instr 316 op neg dests r400
// @trace state 461 instr 317 op broadcast dests r401
// @trace state 462 instr 318 op sub dests r402
// @trace state 463 instr 319 op max dests r403
// @trace state 465 instr 320 op reduce_sum dests r404
// @trace state 466 instr 321 op add dests r405
// @trace state 467 instr 322 op gt dests r406
// @trace state 468 instr 323 op select_n dests r407
// @trace state 469 instr 324 op select_n dests r408
// @trace state 476 instr 308 op loop dests r409 r410 r411
// @trace state 477 instr 325 op abs dests r412
// @trace state 479 instr 326 op reduce_max dests r413
// @trace state 480 instr 327 op sub dests r414
// @trace state 488 instr 329 op add dests r420
// @trace state 489 instr 330 op add dests r421
// @trace state 490 instr 331 op shra dests r422
// @trace state 491 instr 332 op broadcast dests r423
// @trace state 492 instr 333 op sub dests r424
// @trace state 493 instr 334 op max dests r425
// @trace state 495 instr 335 op reduce_sum dests r426
// @trace state 496 instr 336 op neg dests r427
// @trace state 497 instr 337 op broadcast dests r428
// @trace state 498 instr 338 op sub dests r429
// @trace state 499 instr 339 op max dests r430
// @trace state 501 instr 340 op reduce_sum dests r431
// @trace state 502 instr 341 op add dests r432
// @trace state 503 instr 342 op gt dests r433
// @trace state 504 instr 343 op select_n dests r434
// @trace state 505 instr 344 op select_n dests r435
// @trace state 512 instr 328 op loop dests r436 r437 r438
// @trace state 513 instr 345 op sub dests r439
// @trace state 516 instr 282 op loop dests r440
// @trace state 517 instr 346 op transpose dests r441
// @trace state 518 instr 347 op reshape dests r442
// @trace state 519 instr 348 op slice dests r443
// @trace state 520 instr 349 op transpose dests r444
// @trace state 521 instr 350 op slice dests r445
// @trace state 522 instr 351 op reshape dests r446
// @trace state 523 instr 352 op shra dests r447
// @trace state 524 instr 353 op convert dests r448
// @trace state 525 instr 354 op max dests r449
// @trace state 526 instr 355 op convert dests r450
// @trace state 527 instr 356 op min dests r451
// @trace state 528 instr 357 op iota dests r452
// @trace state 529 instr 358 op shl dests r453
// @trace state 530 instr 359 op add dests r454
// @trace state 531 instr 360 op broadcast dests r455
// @trace state 532 instr 361 op gather dests r456
// @trace state 533 instr 362 op shl dests r457
// @trace state 534 instr 363 op mov dests r458
// @trace state 535 instr 364 op rev dests r459
// @trace state 536 instr 365 op reshape dests r460
// @trace state 537 instr 366 op convert dests r461
// @trace state 539 instr 367 op pad dests r462
// @trace state 540 instr 368 op convert dests r463
// @trace state 542 instr 369 op pad dests r464
// @trace state 543 instr 370 op iota dests r465
// @trace state 544 instr 371 op broadcast dests r466
// @trace state 545 instr 372 op iota dests r467
// @trace state 546 instr 373 op broadcast dests r468
// @trace state 547 instr 374 op add dests r469
// @trace state 548 instr 375 op iota dests r470
// @trace state 549 instr 376 op shl dests r471
// @trace state 556 instr 378 op add dests r476
// @trace state 557 instr 379 op select_n dests r477
// @trace state 558 instr 380 op lt dests r478
// @trace state 559 instr 381 op add dests r480
// @trace state 560 instr 382 op select_n dests r481
// @trace state 561 instr 383 op dynamic_slice dests r482
// @trace state 562 instr 384 op lt dests r483
// @trace state 563 instr 385 op add dests r484
// @trace state 564 instr 386 op select_n dests r485
// @trace state 565 instr 387 op broadcast dests r486
// @trace state 566 instr 388 op gather dests r487
// @trace state 567 instr 389 op broadcast dests r488
// @trace state 568 instr 390 op add dests r489
// @trace state 569 instr 391 op convert dests r490
// @trace state 570 instr 392 op max dests r491
// @trace state 571 instr 393 op convert dests r492
// @trace state 572 instr 394 op min dests r493
// @trace state 573 instr 395 op sub dests r494
// @trace state 574 instr 396 op convert dests r495
// @trace state 575 instr 397 op max dests r496
// @trace state 576 instr 398 op convert dests r497
// @trace state 577 instr 399 op min dests r498
// @trace state 578 instr 400 op abs dests r499
// @trace state 580 instr 401 op reduce_max dests r500
// @trace state 581 instr 402 op sub dests r501
// @trace state 589 instr 404 op add dests r507
// @trace state 590 instr 405 op add dests r508
// @trace state 591 instr 406 op shra dests r509
// @trace state 592 instr 407 op broadcast dests r510
// @trace state 593 instr 408 op sub dests r511
// @trace state 594 instr 409 op max dests r512
// @trace state 596 instr 410 op reduce_sum dests r513
// @trace state 597 instr 411 op neg dests r514
// @trace state 598 instr 412 op broadcast dests r515
// @trace state 599 instr 413 op sub dests r516
// @trace state 600 instr 414 op max dests r517
// @trace state 602 instr 415 op reduce_sum dests r518
// @trace state 603 instr 416 op add dests r519
// @trace state 604 instr 417 op gt dests r520
// @trace state 605 instr 418 op select_n dests r521
// @trace state 606 instr 419 op select_n dests r522
// @trace state 613 instr 403 op loop dests r523 r524 r525
// @trace state 614 instr 420 op abs dests r526
// @trace state 616 instr 421 op reduce_max dests r527
// @trace state 617 instr 422 op sub dests r528
// @trace state 625 instr 424 op add dests r534
// @trace state 626 instr 425 op add dests r535
// @trace state 627 instr 426 op shra dests r536
// @trace state 628 instr 427 op broadcast dests r537
// @trace state 629 instr 428 op sub dests r538
// @trace state 630 instr 429 op max dests r539
// @trace state 632 instr 430 op reduce_sum dests r540
// @trace state 633 instr 431 op neg dests r541
// @trace state 634 instr 432 op broadcast dests r542
// @trace state 635 instr 433 op sub dests r543
// @trace state 636 instr 434 op max dests r544
// @trace state 638 instr 435 op reduce_sum dests r545
// @trace state 639 instr 436 op add dests r546
// @trace state 640 instr 437 op gt dests r547
// @trace state 641 instr 438 op select_n dests r548
// @trace state 642 instr 439 op select_n dests r549
// @trace state 649 instr 423 op loop dests r550 r551 r552
// @trace state 650 instr 440 op sub dests r553
// @trace state 653 instr 377 op loop dests r554
// @trace state 654 instr 441 op transpose dests r555
// @trace state 655 instr 442 op reshape dests r556
// @trace state 656 instr 443 op slice dests r557
// @trace state 657 instr 444 op transpose dests r558
// @trace state 658 instr 445 op max dests r559
// @trace state 660 instr 446 op reduce_sum dests r560
// @trace state 661 instr 447 op shl dests r562
// @trace state 662 instr 448 op shl dests r563
// @trace state 663 instr 449 op mov dests r564
// @trace state 664 instr 450 op rev dests r565
// @trace state 665 instr 451 op reshape dests r566
// @trace state 666 instr 452 op convert dests r567
// @trace state 668 instr 453 op pad dests r568
// @trace state 669 instr 454 op convert dests r569
// @trace state 671 instr 455 op pad dests r570
// @trace state 672 instr 456 op iota dests r571
// @trace state 673 instr 457 op broadcast dests r572
// @trace state 674 instr 458 op iota dests r573
// @trace state 675 instr 459 op broadcast dests r574
// @trace state 676 instr 460 op add dests r575
// @trace state 677 instr 461 op iota dests r576
// @trace state 678 instr 462 op shl dests r577
// @trace state 685 instr 464 op add dests r582
// @trace state 686 instr 465 op select_n dests r583
// @trace state 687 instr 466 op lt dests r584
// @trace state 688 instr 467 op add dests r586
// @trace state 689 instr 468 op select_n dests r587
// @trace state 690 instr 469 op dynamic_slice dests r588
// @trace state 691 instr 470 op lt dests r589
// @trace state 692 instr 471 op add dests r590
// @trace state 693 instr 472 op select_n dests r591
// @trace state 694 instr 473 op broadcast dests r592
// @trace state 695 instr 474 op gather dests r593
// @trace state 696 instr 475 op broadcast dests r594
// @trace state 697 instr 476 op add dests r595
// @trace state 698 instr 477 op convert dests r596
// @trace state 699 instr 478 op max dests r597
// @trace state 700 instr 479 op convert dests r598
// @trace state 701 instr 480 op min dests r599
// @trace state 702 instr 481 op sub dests r600
// @trace state 703 instr 482 op convert dests r601
// @trace state 704 instr 483 op max dests r602
// @trace state 705 instr 484 op convert dests r603
// @trace state 706 instr 485 op min dests r604
// @trace state 707 instr 486 op abs dests r605
// @trace state 709 instr 487 op reduce_max dests r606
// @trace state 710 instr 488 op sub dests r607
// @trace state 718 instr 490 op add dests r613
// @trace state 719 instr 491 op add dests r614
// @trace state 720 instr 492 op shra dests r615
// @trace state 721 instr 493 op broadcast dests r616
// @trace state 722 instr 494 op sub dests r617
// @trace state 723 instr 495 op max dests r618
// @trace state 725 instr 496 op reduce_sum dests r619
// @trace state 726 instr 497 op neg dests r620
// @trace state 727 instr 498 op broadcast dests r621
// @trace state 728 instr 499 op sub dests r622
// @trace state 729 instr 500 op max dests r623
// @trace state 731 instr 501 op reduce_sum dests r624
// @trace state 732 instr 502 op add dests r625
// @trace state 733 instr 503 op gt dests r626
// @trace state 734 instr 504 op select_n dests r627
// @trace state 735 instr 505 op select_n dests r628
// @trace state 742 instr 489 op loop dests r629 r630 r631
// @trace state 743 instr 506 op abs dests r632
// @trace state 745 instr 507 op reduce_max dests r633
// @trace state 746 instr 508 op sub dests r634
// @trace state 754 instr 510 op add dests r640
// @trace state 755 instr 511 op add dests r641
// @trace state 756 instr 512 op shra dests r642
// @trace state 757 instr 513 op broadcast dests r643
// @trace state 758 instr 514 op sub dests r644
// @trace state 759 instr 515 op max dests r645
// @trace state 761 instr 516 op reduce_sum dests r646
// @trace state 762 instr 517 op neg dests r647
// @trace state 763 instr 518 op broadcast dests r648
// @trace state 764 instr 519 op sub dests r649
// @trace state 765 instr 520 op max dests r650
// @trace state 767 instr 521 op reduce_sum dests r651
// @trace state 768 instr 522 op add dests r652
// @trace state 769 instr 523 op gt dests r653
// @trace state 770 instr 524 op select_n dests r654
// @trace state 771 instr 525 op select_n dests r655
// @trace state 778 instr 509 op loop dests r656 r657 r658
// @trace state 779 instr 526 op sub dests r659
// @trace state 782 instr 463 op loop dests r660
// @trace state 783 instr 527 op transpose dests r661
// @trace state 784 instr 528 op reshape dests r662
// @trace state 785 instr 529 op slice dests r663
// @trace state 786 instr 530 op transpose dests r664
// @trace state 787 instr 531 op slice dests r665
// @trace state 788 instr 532 op reshape dests r666
// @trace state 789 instr 533 op shra dests r667
// @trace state 790 instr 534 op convert dests r668
// @trace state 791 instr 535 op max dests r669
// @trace state 792 instr 536 op convert dests r670
// @trace state 793 instr 537 op min dests r671
// @trace state 794 instr 538 op iota dests r672
// @trace state 795 instr 539 op shl dests r673
// @trace state 796 instr 540 op add dests r674
// @trace state 797 instr 541 op broadcast dests r675
// @trace state 798 instr 542 op gather dests r676
// @trace state 799 instr 543 op shl dests r677
// @trace state 800 instr 544 op mov dests r678
// @trace state 801 instr 545 op rev dests r679
// @trace state 802 instr 546 op reshape dests r680
// @trace state 803 instr 547 op convert dests r681
// @trace state 805 instr 548 op pad dests r682
// @trace state 806 instr 549 op convert dests r683
// @trace state 808 instr 550 op pad dests r684
// @trace state 809 instr 551 op iota dests r685
// @trace state 810 instr 552 op broadcast dests r686
// @trace state 811 instr 553 op iota dests r687
// @trace state 812 instr 554 op broadcast dests r688
// @trace state 813 instr 555 op add dests r689
// @trace state 814 instr 556 op iota dests r690
// @trace state 815 instr 557 op shl dests r691
// @trace state 822 instr 559 op add dests r696
// @trace state 823 instr 560 op select_n dests r697
// @trace state 824 instr 561 op lt dests r698
// @trace state 825 instr 562 op add dests r700
// @trace state 826 instr 563 op select_n dests r701
// @trace state 827 instr 564 op dynamic_slice dests r702
// @trace state 828 instr 565 op lt dests r703
// @trace state 829 instr 566 op add dests r704
// @trace state 830 instr 567 op select_n dests r705
// @trace state 831 instr 568 op broadcast dests r706
// @trace state 832 instr 569 op gather dests r707
// @trace state 833 instr 570 op broadcast dests r708
// @trace state 834 instr 571 op add dests r709
// @trace state 835 instr 572 op convert dests r710
// @trace state 836 instr 573 op max dests r711
// @trace state 837 instr 574 op convert dests r712
// @trace state 838 instr 575 op min dests r713
// @trace state 839 instr 576 op sub dests r714
// @trace state 840 instr 577 op convert dests r715
// @trace state 841 instr 578 op max dests r716
// @trace state 842 instr 579 op convert dests r717
// @trace state 843 instr 580 op min dests r718
// @trace state 844 instr 581 op abs dests r719
// @trace state 846 instr 582 op reduce_max dests r720
// @trace state 847 instr 583 op sub dests r721
// @trace state 855 instr 585 op add dests r727
// @trace state 856 instr 586 op add dests r728
// @trace state 857 instr 587 op shra dests r729
// @trace state 858 instr 588 op broadcast dests r730
// @trace state 859 instr 589 op sub dests r731
// @trace state 860 instr 590 op max dests r732
// @trace state 862 instr 591 op reduce_sum dests r733
// @trace state 863 instr 592 op neg dests r734
// @trace state 864 instr 593 op broadcast dests r735
// @trace state 865 instr 594 op sub dests r736
// @trace state 866 instr 595 op max dests r737
// @trace state 868 instr 596 op reduce_sum dests r738
// @trace state 869 instr 597 op add dests r739
// @trace state 870 instr 598 op gt dests r740
// @trace state 871 instr 599 op select_n dests r741
// @trace state 872 instr 600 op select_n dests r742
// @trace state 879 instr 584 op loop dests r743 r744 r745
// @trace state 880 instr 601 op abs dests r746
// @trace state 882 instr 602 op reduce_max dests r747
// @trace state 883 instr 603 op sub dests r748
// @trace state 891 instr 605 op add dests r754
// @trace state 892 instr 606 op add dests r755
// @trace state 893 instr 607 op shra dests r756
// @trace state 894 instr 608 op broadcast dests r757
// @trace state 895 instr 609 op sub dests r758
// @trace state 896 instr 610 op max dests r759
// @trace state 898 instr 611 op reduce_sum dests r760
// @trace state 899 instr 612 op neg dests r761
// @trace state 900 instr 613 op broadcast dests r762
// @trace state 901 instr 614 op sub dests r763
// @trace state 902 instr 615 op max dests r764
// @trace state 904 instr 616 op reduce_sum dests r765
// @trace state 905 instr 617 op add dests r766
// @trace state 906 instr 618 op gt dests r767
// @trace state 907 instr 619 op select_n dests r768
// @trace state 908 instr 620 op select_n dests r769
// @trace state 915 instr 604 op loop dests r770 r771 r772
// @trace state 916 instr 621 op sub dests r773
// @trace state 919 instr 558 op loop dests r774
// @trace state 920 instr 622 op transpose dests r775
// @trace state 921 instr 623 op reshape dests r776
// @trace state 922 instr 624 op slice dests r777
// @trace state 923 instr 625 op transpose dests r778
// @trace state 924 instr 626 op max dests r779
// @trace state 926 instr 627 op reduce_sum dests r780
// @trace state 927 instr 628 op shl dests r782
// @trace state 928 instr 629 op shl dests r783
// @trace state 929 instr 630 op mov dests r784
// @trace state 930 instr 631 op rev dests r785
// @trace state 931 instr 632 op reshape dests r786
// @trace state 932 instr 633 op convert dests r787
// @trace state 934 instr 634 op pad dests r788
// @trace state 935 instr 635 op convert dests r789
// @trace state 937 instr 636 op pad dests r790
// @trace state 938 instr 637 op iota dests r791
// @trace state 939 instr 638 op broadcast dests r792
// @trace state 940 instr 639 op iota dests r793
// @trace state 941 instr 640 op broadcast dests r794
// @trace state 942 instr 641 op add dests r795
// @trace state 943 instr 642 op iota dests r796
// @trace state 944 instr 643 op shl dests r797
// @trace state 951 instr 645 op add dests r802
// @trace state 952 instr 646 op select_n dests r803
// @trace state 953 instr 647 op lt dests r804
// @trace state 954 instr 648 op add dests r806
// @trace state 955 instr 649 op select_n dests r807
// @trace state 956 instr 650 op dynamic_slice dests r808
// @trace state 957 instr 651 op lt dests r809
// @trace state 958 instr 652 op add dests r810
// @trace state 959 instr 653 op select_n dests r811
// @trace state 960 instr 654 op broadcast dests r812
// @trace state 961 instr 655 op gather dests r813
// @trace state 962 instr 656 op broadcast dests r814
// @trace state 963 instr 657 op add dests r815
// @trace state 964 instr 658 op convert dests r816
// @trace state 965 instr 659 op max dests r817
// @trace state 966 instr 660 op convert dests r818
// @trace state 967 instr 661 op min dests r819
// @trace state 968 instr 662 op sub dests r820
// @trace state 969 instr 663 op convert dests r821
// @trace state 970 instr 664 op max dests r822
// @trace state 971 instr 665 op convert dests r823
// @trace state 972 instr 666 op min dests r824
// @trace state 973 instr 667 op abs dests r825
// @trace state 975 instr 668 op reduce_max dests r826
// @trace state 976 instr 669 op sub dests r827
// @trace state 984 instr 671 op add dests r833
// @trace state 985 instr 672 op add dests r834
// @trace state 986 instr 673 op shra dests r835
// @trace state 987 instr 674 op broadcast dests r836
// @trace state 988 instr 675 op sub dests r837
// @trace state 989 instr 676 op max dests r838
// @trace state 991 instr 677 op reduce_sum dests r839
// @trace state 992 instr 678 op neg dests r840
// @trace state 993 instr 679 op broadcast dests r841
// @trace state 994 instr 680 op sub dests r842
// @trace state 995 instr 681 op max dests r843
// @trace state 997 instr 682 op reduce_sum dests r844
// @trace state 998 instr 683 op add dests r845
// @trace state 999 instr 684 op gt dests r846
// @trace state 1000 instr 685 op select_n dests r847
// @trace state 1001 instr 686 op select_n dests r848
// @trace state 1008 instr 670 op loop dests r849 r850 r851
// @trace state 1009 instr 687 op abs dests r852
// @trace state 1011 instr 688 op reduce_max dests r853
// @trace state 1012 instr 689 op sub dests r854
// @trace state 1020 instr 691 op add dests r860
// @trace state 1021 instr 692 op add dests r861
// @trace state 1022 instr 693 op shra dests r862
// @trace state 1023 instr 694 op broadcast dests r863
// @trace state 1024 instr 695 op sub dests r864
// @trace state 1025 instr 696 op max dests r865
// @trace state 1027 instr 697 op reduce_sum dests r866
// @trace state 1028 instr 698 op neg dests r867
// @trace state 1029 instr 699 op broadcast dests r868
// @trace state 1030 instr 700 op sub dests r869
// @trace state 1031 instr 701 op max dests r870
// @trace state 1033 instr 702 op reduce_sum dests r871
// @trace state 1034 instr 703 op add dests r872
// @trace state 1035 instr 704 op gt dests r873
// @trace state 1036 instr 705 op select_n dests r874
// @trace state 1037 instr 706 op select_n dests r875
// @trace state 1044 instr 690 op loop dests r876 r877 r878
// @trace state 1045 instr 707 op sub dests r879
// @trace state 1048 instr 644 op loop dests r880
// @trace state 1049 instr 708 op transpose dests r881
// @trace state 1050 instr 709 op reshape dests r882
// @trace state 1051 instr 710 op slice dests r883
// @trace state 1052 instr 711 op transpose dests r884
// @trace state 1053 instr 712 op slice dests r885
// @trace state 1054 instr 713 op reshape dests r886
// @trace state 1055 instr 714 op shra dests r887
// @trace state 1056 instr 715 op convert dests r888
// @trace state 1057 instr 716 op max dests r889
// @trace state 1058 instr 717 op convert dests r890
// @trace state 1059 instr 718 op min dests r891
// @trace state 1060 instr 719 op iota dests r892
// @trace state 1061 instr 720 op shl dests r893
// @trace state 1062 instr 721 op add dests r894
// @trace state 1063 instr 722 op broadcast dests r895
// @trace state 1064 instr 723 op gather dests r896
// @trace state 1065 instr 724 op shl dests r897
// @trace state 1066 instr 725 op mov dests r898
// @trace state 1067 instr 726 op rev dests r899
// @trace state 1068 instr 727 op reshape dests r900
// @trace state 1069 instr 728 op convert dests r901
// @trace state 1071 instr 729 op pad dests r902
// @trace state 1072 instr 730 op iota dests r903
// @trace state 1073 instr 731 op broadcast dests r904
// @trace state 1074 instr 732 op iota dests r905
// @trace state 1075 instr 733 op broadcast dests r906
// @trace state 1076 instr 734 op add dests r907
// @trace state 1077 instr 735 op lt dests r908
// @trace state 1078 instr 736 op add dests r910
// @trace state 1079 instr 737 op select_n dests r911
// @trace state 1080 instr 738 op broadcast dests r912
// @trace state 1081 instr 739 op gather dests r913
// @trace state 1082 instr 740 op broadcast dests r914
// @trace state 1083 instr 741 op add dests r915
// @trace state 1084 instr 742 op convert dests r916
// @trace state 1085 instr 743 op max dests r917
// @trace state 1086 instr 744 op convert dests r918
// @trace state 1087 instr 745 op min dests r919
// @trace state 1088 instr 746 op sub dests r920
// @trace state 1089 instr 747 op convert dests r921
// @trace state 1090 instr 748 op max dests r922
// @trace state 1091 instr 749 op convert dests r923
// @trace state 1092 instr 750 op min dests r924
// @trace state 1093 instr 751 op abs dests r925
// @trace state 1095 instr 752 op reduce_max dests r926
// @trace state 1096 instr 753 op sub dests r927
// @trace state 1104 instr 755 op add dests r933
// @trace state 1105 instr 756 op add dests r934
// @trace state 1106 instr 757 op shra dests r935
// @trace state 1107 instr 758 op broadcast dests r936
// @trace state 1108 instr 759 op sub dests r937
// @trace state 1109 instr 760 op max dests r938
// @trace state 1111 instr 761 op reduce_sum dests r939
// @trace state 1112 instr 762 op neg dests r940
// @trace state 1113 instr 763 op broadcast dests r941
// @trace state 1114 instr 764 op sub dests r942
// @trace state 1115 instr 765 op max dests r943
// @trace state 1117 instr 766 op reduce_sum dests r944
// @trace state 1118 instr 767 op add dests r945
// @trace state 1119 instr 768 op gt dests r946
// @trace state 1120 instr 769 op select_n dests r947
// @trace state 1121 instr 770 op select_n dests r948
// @trace state 1128 instr 754 op loop dests r949 r950 r951
// @trace state 1129 instr 771 op abs dests r952
// @trace state 1131 instr 772 op reduce_max dests r953
// @trace state 1132 instr 773 op sub dests r954
// @trace state 1140 instr 775 op add dests r960
// @trace state 1141 instr 776 op add dests r961
// @trace state 1142 instr 777 op shra dests r962
// @trace state 1143 instr 778 op broadcast dests r963
// @trace state 1144 instr 779 op sub dests r964
// @trace state 1145 instr 780 op max dests r965
// @trace state 1147 instr 781 op reduce_sum dests r966
// @trace state 1148 instr 782 op neg dests r967
// @trace state 1149 instr 783 op broadcast dests r968
// @trace state 1150 instr 784 op sub dests r969
// @trace state 1151 instr 785 op max dests r970
// @trace state 1153 instr 786 op reduce_sum dests r971
// @trace state 1154 instr 787 op add dests r972
// @trace state 1155 instr 788 op gt dests r973
// @trace state 1156 instr 789 op select_n dests r974
// @trace state 1157 instr 790 op select_n dests r975
// @trace state 1164 instr 774 op loop dests r976 r977 r978
// @trace state 1165 instr 791 op sub dests r979
// @trace state 1166 instr 792 op transpose dests r980
// @trace state 1167 instr 793 op max dests r981
// @trace state 1169 instr 794 op reduce_sum dests r982
// @trace state 1170 instr 795 op shl dests r984
// @trace state 1171 instr 796 op shl dests r985
// @trace state 1172 instr 797 op mov dests r986
// @trace state 1173 instr 798 op rev dests r987
// @trace state 1174 instr 799 op reshape dests r988
// @trace state 1175 instr 800 op convert dests r989
// @trace state 1177 instr 801 op pad dests r990
// @trace state 1178 instr 802 op iota dests r991
// @trace state 1179 instr 803 op broadcast dests r992
// @trace state 1180 instr 804 op iota dests r993
// @trace state 1181 instr 805 op broadcast dests r994
// @trace state 1182 instr 806 op add dests r995
// @trace state 1183 instr 807 op lt dests r996
// @trace state 1184 instr 808 op add dests r998
// @trace state 1185 instr 809 op select_n dests r999
// @trace state 1186 instr 810 op broadcast dests r1000
// @trace state 1187 instr 811 op gather dests r1001
// @trace state 1188 instr 812 op broadcast dests r1002
// @trace state 1189 instr 813 op add dests r1003
// @trace state 1190 instr 814 op convert dests r1004
// @trace state 1191 instr 815 op max dests r1005
// @trace state 1192 instr 816 op convert dests r1006
// @trace state 1193 instr 817 op min dests r1007
// @trace state 1194 instr 818 op sub dests r1008
// @trace state 1195 instr 819 op convert dests r1009
// @trace state 1196 instr 820 op max dests r1010
// @trace state 1197 instr 821 op convert dests r1011
// @trace state 1198 instr 822 op min dests r1012
// @trace state 1199 instr 823 op abs dests r1013
// @trace state 1201 instr 824 op reduce_max dests r1014
// @trace state 1202 instr 825 op sub dests r1015
// @trace state 1210 instr 827 op add dests r1021
// @trace state 1211 instr 828 op add dests r1022
// @trace state 1212 instr 829 op shra dests r1023
// @trace state 1213 instr 830 op broadcast dests r1024
// @trace state 1214 instr 831 op sub dests r1025
// @trace state 1215 instr 832 op max dests r1026
// @trace state 1217 instr 833 op reduce_sum dests r1027
// @trace state 1218 instr 834 op neg dests r1028
// @trace state 1219 instr 835 op broadcast dests r1029
// @trace state 1220 instr 836 op sub dests r1030
// @trace state 1221 instr 837 op max dests r1031
// @trace state 1223 instr 838 op reduce_sum dests r1032
// @trace state 1224 instr 839 op add dests r1033
// @trace state 1225 instr 840 op gt dests r1034
// @trace state 1226 instr 841 op select_n dests r1035
// @trace state 1227 instr 842 op select_n dests r1036
// @trace state 1234 instr 826 op loop dests r1037 r1038 r1039
// @trace state 1235 instr 843 op abs dests r1040
// @trace state 1237 instr 844 op reduce_max dests r1041
// @trace state 1238 instr 845 op sub dests r1042
// @trace state 1246 instr 847 op add dests r1048
// @trace state 1247 instr 848 op add dests r1049
// @trace state 1248 instr 849 op shra dests r1050
// @trace state 1249 instr 850 op broadcast dests r1051
// @trace state 1250 instr 851 op sub dests r1052
// @trace state 1251 instr 852 op max dests r1053
// @trace state 1253 instr 853 op reduce_sum dests r1054
// @trace state 1254 instr 854 op neg dests r1055
// @trace state 1255 instr 855 op broadcast dests r1056
// @trace state 1256 instr 856 op sub dests r1057
// @trace state 1257 instr 857 op max dests r1058
// @trace state 1259 instr 858 op reduce_sum dests r1059
// @trace state 1260 instr 859 op add dests r1060
// @trace state 1261 instr 860 op gt dests r1061
// @trace state 1262 instr 861 op select_n dests r1062
// @trace state 1263 instr 862 op select_n dests r1063
// @trace state 1270 instr 846 op loop dests r1064 r1065 r1066
// @trace state 1271 instr 863 op sub dests r1067
// @trace state 1272 instr 864 op transpose dests r1068
// @trace state 1273 instr 865 op slice dests r1069
// @trace state 1274 instr 866 op reshape dests r1070
// @trace state 1275 instr 867 op shra dests r1071
// @trace state 1276 instr 868 op convert dests r1072
// @trace state 1277 instr 869 op max dests r1073
// @trace state 1278 instr 870 op convert dests r1074
// @trace state 1279 instr 871 op min dests r1075
// @trace state 1280 instr 872 op iota dests r1076
// @trace state 1281 instr 873 op shl dests r1077
// @trace state 1282 instr 874 op add dests r1078
// @trace state 1283 instr 875 op broadcast dests r1079
// @trace state 1284 instr 876 op gather dests r1080
// @trace state 1285 instr 877 op shl dests r1081
// @trace state 1286 instr 878 op mov dests r1082
// @trace state 1287 instr 879 op rev dests r1083
// @trace state 1288 instr 880 op reshape dests r1084
// @trace state 1289 instr 881 op convert dests r1085
// @trace state 1291 instr 882 op pad dests r1086
// @trace state 1292 instr 883 op iota dests r1087
// @trace state 1293 instr 884 op broadcast dests r1088
// @trace state 1294 instr 885 op iota dests r1089
// @trace state 1295 instr 886 op broadcast dests r1090
// @trace state 1296 instr 887 op add dests r1091
// @trace state 1297 instr 888 op lt dests r1092
// @trace state 1298 instr 889 op add dests r1094
// @trace state 1299 instr 890 op select_n dests r1095
// @trace state 1300 instr 891 op broadcast dests r1096
// @trace state 1301 instr 892 op gather dests r1097
// @trace state 1302 instr 893 op broadcast dests r1098
// @trace state 1303 instr 894 op add dests r1099
// @trace state 1304 instr 895 op convert dests r1100
// @trace state 1305 instr 896 op max dests r1101
// @trace state 1306 instr 897 op convert dests r1102
// @trace state 1307 instr 898 op min dests r1103
// @trace state 1308 instr 899 op sub dests r1104
// @trace state 1309 instr 900 op convert dests r1105
// @trace state 1310 instr 901 op max dests r1106
// @trace state 1311 instr 902 op convert dests r1107
// @trace state 1312 instr 903 op min dests r1108
// @trace state 1313 instr 904 op abs dests r1109
// @trace state 1315 instr 905 op reduce_max dests r1110
// @trace state 1316 instr 906 op sub dests r1111
// @trace state 1324 instr 908 op add dests r1117
// @trace state 1325 instr 909 op add dests r1118
// @trace state 1326 instr 910 op shra dests r1119
// @trace state 1327 instr 911 op broadcast dests r1120
// @trace state 1328 instr 912 op sub dests r1121
// @trace state 1329 instr 913 op max dests r1122
// @trace state 1331 instr 914 op reduce_sum dests r1123
// @trace state 1332 instr 915 op neg dests r1124
// @trace state 1333 instr 916 op broadcast dests r1125
// @trace state 1334 instr 917 op sub dests r1126
// @trace state 1335 instr 918 op max dests r1127
// @trace state 1337 instr 919 op reduce_sum dests r1128
// @trace state 1338 instr 920 op add dests r1129
// @trace state 1339 instr 921 op gt dests r1130
// @trace state 1340 instr 922 op select_n dests r1131
// @trace state 1341 instr 923 op select_n dests r1132
// @trace state 1348 instr 907 op loop dests r1133 r1134 r1135
// @trace state 1349 instr 924 op abs dests r1136
// @trace state 1351 instr 925 op reduce_max dests r1137
// @trace state 1352 instr 926 op sub dests r1138
// @trace state 1360 instr 928 op add dests r1144
// @trace state 1361 instr 929 op add dests r1145
// @trace state 1362 instr 930 op shra dests r1146
// @trace state 1363 instr 931 op broadcast dests r1147
// @trace state 1364 instr 932 op sub dests r1148
// @trace state 1365 instr 933 op max dests r1149
// @trace state 1367 instr 934 op reduce_sum dests r1150
// @trace state 1368 instr 935 op neg dests r1151
// @trace state 1369 instr 936 op broadcast dests r1152
// @trace state 1370 instr 937 op sub dests r1153
// @trace state 1371 instr 938 op max dests r1154
// @trace state 1373 instr 939 op reduce_sum dests r1155
// @trace state 1374 instr 940 op add dests r1156
// @trace state 1375 instr 941 op gt dests r1157
// @trace state 1376 instr 942 op select_n dests r1158
// @trace state 1377 instr 943 op select_n dests r1159
// @trace state 1384 instr 927 op loop dests r1160 r1161 r1162
// @trace state 1385 instr 944 op sub dests r1163
// @trace state 1386 instr 945 op transpose dests r1164
// @trace state 1387 instr 946 op max dests r1165
// @trace state 1389 instr 947 op reduce_sum dests r1166
// @trace state 1390 instr 948 op shl dests r1168
// @trace state 1396 instr 949 op concat dests r1169
// @trace state 1397 instr 950 op mov dests r1170
// @trace state 1398 instr 951 op broadcast dests r1171
// @trace state 1399 instr 952 op sub dests r1172
// @trace state 1400 instr 953 op mov dests r1173
// @trace state 1401 instr 954 op ge dests r1174
// @trace state 1402 instr 955 op max dests r1175
// @trace state 1403 instr 956 op broadcast dests r1176
// @trace state 1404 instr 957 op shl dests r1177
// @trace state 1405 instr 958 op neg dests r1178
// @trace state 1406 instr 959 op max dests r1179
// @trace state 1407 instr 960 op broadcast dests r1180
// @trace state 1408 instr 961 op shra dests r1181
// @trace state 1409 instr 962 op broadcast dests r1182
// @trace state 1410 instr 963 op select_n dests r1183
// @trace state 1411 instr 964 op mov dests r1184
// @trace state 1412 instr 965 op ge dests r1185
// @trace state 1413 instr 966 op max dests r1186
// @trace state 1414 instr 967 op broadcast dests r1187
// @trace state 1415 instr 968 op shl dests r1188
// @trace state 1416 instr 969 op neg dests r1189
// @trace state 1417 instr 970 op max dests r1190
// @trace state 1418 instr 971 op broadcast dests r1191
// @trace state 1419 instr 972 op shra dests r1192
// @trace state 1420 instr 973 op broadcast dests r1193
// @trace state 1421 instr 974 op select_n dests r1194
// @trace state 1422 instr 975 op mov dests r1195
// @trace state 1423 instr 976 op gt dests r1196
// @trace state 1424 instr 977 op add dests r1197
// @trace state 1425 instr 978 op lt dests r1198
// @trace state 1426 instr 979 op sub dests r1199
// @trace state 1427 instr 980 op broadcast dests r1200
// @trace state 1428 instr 981 op select_n dests r1201
// @trace state 1429 instr 982 op broadcast dests r1202
// @trace state 1430 instr 983 op select_n dests r1203
// @trace state 1431 instr 984 op convert dests r1204
// @trace state 1432 instr 985 op max dests r1205
// @trace state 1433 instr 986 op convert dests r1206
// @trace state 1434 instr 987 op min dests r1207
// @trace state 1435 instr 988 op shl dests r1208
// @trace state 1436 instr 989 op broadcast dests r1209
// @trace state 1437 instr 990 op broadcast dests r1210
// @trace state 1438 instr 991 op neg dests r1211
// @trace state 1439 instr 992 op mov dests r1212
// @trace state 1440 instr 993 op mov dests r1213
// @trace state 1441 instr 994 op broadcast dests r1214
// @trace state 1442 instr 995 op add dests r1215
// @trace state 1443 instr 996 op convert dests r1216
// @trace state 1444 instr 997 op max dests r1217
// @trace state 1445 instr 998 op convert dests r1218
// @trace state 1446 instr 999 op min dests r1219
// @trace state 1447 instr 1000 op broadcast dests r1220
// @trace state 1448 instr 1001 op add dests r1221
// @trace state 1449 instr 1002 op convert dests r1222
// @trace state 1450 instr 1003 op max dests r1223
// @trace state 1451 instr 1004 op convert dests r1224
// @trace state 1452 instr 1005 op min dests r1225
// @trace state 1454 instr 1006 op concat dests r1226
// @trace state 1455 instr 1007 op mov dests r1227
// @trace state 1456 instr 1008 op broadcast dests r1228
// @trace state 1458 instr 1009 op concat dests r1229
// @trace state 1459 instr 1010 op transpose dests r1230
// @trace state 1461 instr 1011 op reduce_max dests r1231
// @trace state 1462 instr 1012 op sub dests r1233
// @trace state 1470 instr 1014 op add dests r1239
// @trace state 1471 instr 1015 op add dests r1240
// @trace state 1472 instr 1016 op shra dests r1241
// @trace state 1473 instr 1017 op broadcast dests r1242
// @trace state 1474 instr 1018 op sub dests r1243
// @trace state 1475 instr 1019 op max dests r1244
// @trace state 1477 instr 1020 op reduce_sum dests r1245
// @trace state 1478 instr 1021 op gt dests r1246
// @trace state 1479 instr 1022 op select_n dests r1247
// @trace state 1480 instr 1023 op select_n dests r1248
// @trace state 1487 instr 1013 op loop dests r1249 r1250 r1251
// @trace state 1488 instr 1024 op broadcast dests r1252
// @trace state 1489 instr 1025 op add dests r1253
// @trace state 1490 instr 1026 op convert dests r1254
// @trace state 1491 instr 1027 op max dests r1255
// @trace state 1492 instr 1028 op convert dests r1256
// @trace state 1493 instr 1029 op min dests r1257
// @trace state 1494 instr 1030 op broadcast dests r1258
// @trace state 1495 instr 1031 op add dests r1259
// @trace state 1496 instr 1032 op convert dests r1260
// @trace state 1497 instr 1033 op max dests r1261
// @trace state 1498 instr 1034 op convert dests r1262
// @trace state 1499 instr 1035 op min dests r1263
// @trace state 1501 instr 1036 op concat dests r1264
// @trace state 1502 instr 1037 op mov dests r1265
// @trace state 1503 instr 1038 op broadcast dests r1266
// @trace state 1505 instr 1039 op concat dests r1267
// @trace state 1506 instr 1040 op transpose dests r1268
// @trace state 1508 instr 1041 op reduce_max dests r1269
// @trace state 1509 instr 1042 op sub dests r1270
// @trace state 1517 instr 1044 op add dests r1276
// @trace state 1518 instr 1045 op add dests r1277
// @trace state 1519 instr 1046 op shra dests r1278
// @trace state 1520 instr 1047 op broadcast dests r1279
// @trace state 1521 instr 1048 op sub dests r1280
// @trace state 1522 instr 1049 op max dests r1281
// @trace state 1524 instr 1050 op reduce_sum dests r1282
// @trace state 1525 instr 1051 op gt dests r1283
// @trace state 1526 instr 1052 op select_n dests r1284
// @trace state 1527 instr 1053 op select_n dests r1285
// @trace state 1534 instr 1043 op loop dests r1286 r1287 r1288
// @trace state 1535 instr 1054 op broadcast dests r1289
// @trace state 1536 instr 1055 op broadcast dests r1290
// @trace state 1538 instr 1056 op concat dests r1291
// @trace state 1540 instr 1057 op reduce_max dests r1292
// @trace state 1541 instr 1058 op sub dests r1294
// @trace state 1549 instr 1060 op add dests r1300
// @trace state 1550 instr 1061 op add dests r1301
// @trace state 1551 instr 1062 op shra dests r1302
// @trace state 1552 instr 1063 op broadcast dests r1303
// @trace state 1553 instr 1064 op sub dests r1304
// @trace state 1554 instr 1065 op max dests r1305
// @trace state 1556 instr 1066 op reduce_sum dests r1306
// @trace state 1557 instr 1067 op gt dests r1307
// @trace state 1558 instr 1068 op select_n dests r1308
// @trace state 1559 instr 1069 op select_n dests r1309
// @trace state 1566 instr 1059 op loop dests r1310 r1311 r1312
// @trace state 1567 instr 1070 op sub dests r1313
// @trace state 1568 instr 1071 op max dests r1314
// @trace state 1569 instr 1072 op sub dests r1315
// @trace state 1570 instr 1073 op max dests r1316
// @trace state 1571 instr 1074 op sub dests r1317

module oneshot_q(input wire clk, input wire rst, input wire start, output reg done);
  reg signed [7:0] r0 [0:15999];
  reg signed [8:0] r10 [0:15999];
  reg signed [5:0] r11 [0:79];
  reg signed [5:0] r12 [0:79];
  reg signed [5:0] r13 [0:79];
  reg signed [0:0] r15 [0:0];
  reg signed [8:0] r16 [0:16014];
  reg signed [0:0] r17 [0:0];
  reg signed [8:0] r18 [0:16398];
  reg signed [10:0] r19 [0:1023];
  reg signed [10:0] r20 [0:1023];
  reg signed [4:0] r21 [0:15];
  reg signed [4:0] r22 [0:15];
  reg signed [11:0] r23 [0:16383];
  reg signed [4:0] r24 [0:15];
  reg signed [14:0] r25 [0:15];
  reg signed [31:0] r26 [0:16398];
  reg signed [31:0] r27 [0:16383];
  reg signed [31:0] r28 [0:79];
  reg signed [31:0] r29 [0:0];
  reg signed [1:0] r30 [0:0];
  reg signed [0:0] r32 [0:0];
  reg r33 [0:0];
  reg signed [15:0] r35 [0:0];
  reg signed [14:0] r36 [0:0];
  reg signed [8:0] r37 [0:1038];
  reg r38 [0:16383];
  reg signed [12:0] r40 [0:16383];
  reg signed [11:0] r41 [0:16383];
  reg signed [11:0] r42 [0:16383];
  reg signed [8:0] r43 [0:16383];
  reg signed [8:0] r44 [0:16383];
  reg signed [9:0] r45 [0:81919];
  reg signed [9:0] r48 [0:0];
  reg signed [9:0] r49 [0:81919];
  reg signed [9:0] r50 [0:0];
  reg signed [9:0] r51 [0:81919];
  reg signed [9:0] r52 [0:81919];
  reg signed [9:0] r53 [0:0];
  reg signed [9:0] r54 [0:81919];
  reg signed [9:0] r55 [0:0];
  reg signed [9:0] r56 [0:81919];
  reg signed [9:0] r57 [0:81919];
  reg signed [9:0] r58 [0:5119];
  reg signed [9:0] r60 [0:5119];
  reg signed [31:0] r61 [0:81919];
  reg signed [31:0] r62 [0:0];
  reg signed [31:0] r63 [0:0];
  reg signed [31:0] r64 [0:5119];
  reg signed [31:0] r65 [0:5119];
  reg signed [4:0] r66 [0:0];
  reg signed [10:0] r67 [0:5119];
  reg signed [9:0] r68 [0:5119];
  reg signed [9:0] r69 [0:5119];
  reg signed [10:0] r70 [0:81919];
  reg signed [10:0] r71 [0:81919];
  reg signed [14:0] r72 [0:5119];
  reg signed [9:0] r73 [0:81919];
  reg signed [9:0] r74 [0:5119];
  reg signed [10:0] r75 [0:81919];
  reg signed [10:0] r76 [0:81919];
  reg signed [14:0] r77 [0:5119];
  reg signed [15:0] r78 [0:5119];
  reg r79 [0:5119];
  reg signed [9:0] r80 [0:5119];
  reg signed [9:0] r81 [0:5119];
  reg signed [9:0] r82 [0:0];
  reg signed [9:0] r83 [0:5119];
  reg signed [9:0] r84 [0:5119];
  reg signed [9:0] r85 [0:81919];
  reg signed [9:0] r86 [0:5119];
  reg signed [9:0] r87 [0:5119];
  reg signed [31:0] r88 [0:81919];
  reg signed [31:0] r89 [0:0];
  reg signed [31:0] r90 [0:0];
  reg signed [31:0] r91 [0:5119];
  reg signed [31:0] r92 [0:5119];
  reg signed [4:0] r93 [0:0];
  reg signed [10:0] r94 [0:5119];
  reg signed [9:0] r95 [0:5119];
  reg signed [9:0] r96 [0:5119];
  reg signed [10:0] r97 [0:81919];
  reg signed [10:0] r98 [0:81919];
  reg signed [14:0] r99 [0:5119];
  reg signed [9:0] r100 [0:81919];
  reg signed [9:0] r101 [0:5119];
  reg signed [10:0] r102 [0:81919];
  reg signed [10:0] r103 [0:81919];
  reg signed [14:0] r104 [0:5119];
  reg signed [15:0] r105 [0:5119];
  reg r106 [0:5119];
  reg signed [9:0] r107 [0:5119];
  reg signed [9:0] r108 [0:5119];
  reg signed [9:0] r109 [0:0];
  reg signed [9:0] r110 [0:5119];
  reg signed [9:0] r111 [0:5119];
  reg signed [10:0] r112 [0:5119];
  reg signed [10:0] r113 [0:81919];
  reg signed [10:0] r114 [0:81919];
  reg signed [10:0] r115 [0:81919];
  reg signed [10:0] r116 [0:79999];
  reg signed [10:0] r117 [0:79999];
  reg signed [10:0] r118 [0:79999];
  reg signed [24:0] r119 [0:4];
  reg signed [24:0] r120 [0:4];
  reg signed [8:0] r121 [0:15999];
  reg signed [6:0] r122 [0:5];
  reg signed [6:0] r123 [0:5];
  reg signed [6:0] r124 [0:5];
  reg signed [0:0] r125 [0:0];
  reg signed [8:0] r126 [0:16004];
  reg signed [0:0] r127 [0:0];
  reg signed [8:0] r128 [0:16388];
  reg signed [10:0] r129 [0:1023];
  reg signed [10:0] r130 [0:1023];
  reg signed [3:0] r131 [0:5];
  reg signed [3:0] r132 [0:5];
  reg signed [11:0] r133 [0:6143];
  reg signed [4:0] r134 [0:15];
  reg signed [14:0] r135 [0:15];
  reg signed [31:0] r136 [0:16388];
  reg signed [31:0] r137 [0:6143];
  reg signed [31:0] r138 [0:5];
  reg signed [31:0] r139 [0:0];
  reg signed [1:0] r140 [0:0];
  reg signed [0:0] r141 [0:0];
  reg r142 [0:0];
  reg signed [15:0] r144 [0:0];
  reg signed [14:0] r145 [0:0];
  reg signed [8:0] r146 [0:1028];
  reg r147 [0:6143];
  reg signed [12:0] r149 [0:6143];
  reg signed [11:0] r150 [0:6143];
  reg signed [11:0] r151 [0:6143];
  reg signed [8:0] r152 [0:6143];
  reg signed [8:0] r153 [0:6143];
  reg signed [9:0] r154 [0:6143];
  reg signed [9:0] r155 [0:0];
  reg signed [9:0] r156 [0:6143];
  reg signed [9:0] r157 [0:0];
  reg signed [9:0] r158 [0:6143];
  reg signed [9:0] r159 [0:6143];
  reg signed [9:0] r160 [0:0];
  reg signed [9:0] r161 [0:6143];
  reg signed [9:0] r162 [0:0];
  reg signed [9:0] r163 [0:6143];
  reg signed [9:0] r164 [0:6143];
  reg signed [9:0] r165 [0:1023];
  reg signed [9:0] r166 [0:1023];
  reg signed [31:0] r167 [0:6143];
  reg signed [31:0] r168 [0:0];
  reg signed [31:0] r169 [0:0];
  reg signed [31:0] r170 [0:1023];
  reg signed [31:0] r171 [0:1023];
  reg signed [4:0] r172 [0:0];
  reg signed [10:0] r173 [0:1023];
  reg signed [9:0] r174 [0:1023];
  reg signed [9:0] r175 [0:1023];
  reg signed [10:0] r176 [0:6143];
  reg signed [10:0] r177 [0:6143];
  reg signed [13:0] r178 [0:1023];
  reg signed [9:0] r179 [0:6143];
  reg signed [9:0] r180 [0:1023];
  reg signed [10:0] r181 [0:6143];
  reg signed [10:0] r182 [0:6143];
  reg signed [13:0] r183 [0:1023];
  reg signed [14:0] r184 [0:1023];
  reg r185 [0:1023];
  reg signed [9:0] r186 [0:1023];
  reg signed [9:0] r187 [0:1023];
  reg signed [9:0] r188 [0:0];
  reg signed [9:0] r189 [0:1023];
  reg signed [9:0] r190 [0:1023];
  reg signed [9:0] r191 [0:6143];
  reg signed [9:0] r192 [0:1023];
  reg signed [9:0] r193 [0:1023];
  reg signed [31:0] r194 [0:6143];
  reg signed [31:0] r195 [0:0];
  reg signed [31:0] r196 [0:0];
  reg signed [31:0] r197 [0:1023];
  reg signed [31:0] r198 [0:1023];
  reg signed [4:0] r199 [0:0];
  reg signed [10:0] r200 [0:1023];
  reg signed [9:0] r201 [0:1023];
  reg signed [9:0] r202 [0:1023];
  reg signed [10:0] r203 [0:6143];
  reg signed [10:0] r204 [0:6143];
  reg signed [13:0] r205 [0:1023];
  reg signed [9:0] r206 [0:6143];
  reg signed [9:0] r207 [0:1023];
  reg signed [10:0] r208 [0:6143];
  reg signed [10:0] r209 [0:6143];
  reg signed [13:0] r210 [0:1023];
  reg signed [14:0] r211 [0:1023];
  reg r212 [0:1023];
  reg signed [9:0] r213 [0:1023];
  reg signed [9:0] r214 [0:1023];
  reg signed [9:0] r215 [0:0];
  reg signed [9:0] r216 [0:1023];
  reg signed [9:0] r217 [0:1023];
  reg signed [10:0] r218 [0:1023];
  reg signed [10:0] r219 [0:16383];
  reg signed [10:0] r220 [0:16383];
  reg signed [10:0] r221 [0:16383];
  reg signed [10:0] r222 [0:15999];
  reg signed [10:0] r223 [0:15999];
  reg signed [10:0] r224 [0:15999];
  reg signed [10:0] r225 [0:15999];
  reg signed [9:0] r226 [0:15999];
  reg signed [7:0] r229 [0:0];
  reg signed [9:0] r230 [0:15999];
  reg signed [7:0] r231 [0:0];
  reg signed [7:0] r232 [0:15999];
  reg signed [13:0] r233 [0:7999];
  reg signed [14:0] r234 [0:7999];
  reg signed [14:0] r235 [0:7999];
  reg signed [14:0] r236 [0:7999];
  reg signed [7:0] r237 [0:7999];
  reg signed [8:0] r238 [0:7999];
  reg signed [5:0] r239 [0:79];
  reg signed [5:0] r240 [0:79];
  reg signed [5:0] r241 [0:79];
  reg signed [0:0] r242 [0:0];
  reg signed [8:0] r243 [0:8014];
  reg signed [0:0] r244 [0:0];
  reg signed [8:0] r245 [0:8206];
  reg signed [10:0] r246 [0:1023];
  reg signed [10:0] r247 [0:1023];
  reg signed [4:0] r248 [0:15];
  reg signed [4:0] r249 [0:15];
  reg signed [11:0] r250 [0:16383];
  reg signed [3:0] r251 [0:7];
  reg signed [13:0] r252 [0:7];
  reg signed [31:0] r253 [0:8206];
  reg signed [31:0] r254 [0:16383];
  reg signed [31:0] r255 [0:79];
  reg signed [31:0] r256 [0:0];
  reg signed [1:0] r257 [0:0];
  reg signed [0:0] r258 [0:0];
  reg r259 [0:0];
  reg signed [14:0] r261 [0:0];
  reg signed [13:0] r262 [0:0];
  reg signed [8:0] r263 [0:1038];
  reg r264 [0:16383];
  reg signed [12:0] r265 [0:16383];
  reg signed [11:0] r266 [0:16383];
  reg signed [11:0] r267 [0:16383];
  reg signed [8:0] r268 [0:16383];
  reg signed [8:0] r269 [0:16383];
  reg signed [9:0] r270 [0:81919];
  reg signed [9:0] r271 [0:0];
  reg signed [9:0] r272 [0:81919];
  reg signed [9:0] r273 [0:0];
  reg signed [9:0] r274 [0:81919];
  reg signed [9:0] r275 [0:81919];
  reg signed [9:0] r276 [0:0];
  reg signed [9:0] r277 [0:81919];
  reg signed [9:0] r278 [0:0];
  reg signed [9:0] r279 [0:81919];
  reg signed [9:0] r280 [0:81919];
  reg signed [9:0] r281 [0:5119];
  reg signed [9:0] r282 [0:5119];
  reg signed [31:0] r283 [0:81919];
  reg signed [31:0] r284 [0:0];
  reg signed [31:0] r285 [0:0];
  reg signed [31:0] r286 [0:5119];
  reg signed [31:0] r287 [0:5119];
  reg signed [4:0] r288 [0:0];
  reg signed [10:0] r289 [0:5119];
  reg signed [9:0] r290 [0:5119];
  reg signed [9:0] r291 [0:5119];
  reg signed [10:0] r292 [0:81919];
  reg signed [10:0] r293 [0:81919];
  reg signed [14:0] r294 [0:5119];
  reg signed [9:0] r295 [0:81919];
  reg signed [9:0] r296 [0:5119];
  reg signed [10:0] r297 [0:81919];
  reg signed [10:0] r298 [0:81919];
  reg signed [14:0] r299 [0:5119];
  reg signed [15:0] r300 [0:5119];
  reg r301 [0:5119];
  reg signed [9:0] r302 [0:5119];
  reg signed [9:0] r303 [0:5119];
  reg signed [9:0] r304 [0:0];
  reg signed [9:0] r305 [0:5119];
  reg signed [9:0] r306 [0:5119];
  reg signed [9:0] r307 [0:81919];
  reg signed [9:0] r308 [0:5119];
  reg signed [9:0] r309 [0:5119];
  reg signed [31:0] r310 [0:81919];
  reg signed [31:0] r311 [0:0];
  reg signed [31:0] r312 [0:0];
  reg signed [31:0] r313 [0:5119];
  reg signed [31:0] r314 [0:5119];
  reg signed [4:0] r315 [0:0];
  reg signed [10:0] r316 [0:5119];
  reg signed [9:0] r317 [0:5119];
  reg signed [9:0] r318 [0:5119];
  reg signed [10:0] r319 [0:81919];
  reg signed [10:0] r320 [0:81919];
  reg signed [14:0] r321 [0:5119];
  reg signed [9:0] r322 [0:81919];
  reg signed [9:0] r323 [0:5119];
  reg signed [10:0] r324 [0:81919];
  reg signed [10:0] r325 [0:81919];
  reg signed [14:0] r326 [0:5119];
  reg signed [15:0] r327 [0:5119];
  reg r328 [0:5119];
  reg signed [9:0] r329 [0:5119];
  reg signed [9:0] r330 [0:5119];
  reg signed [9:0] r331 [0:0];
  reg signed [9:0] r332 [0:5119];
  reg signed [9:0] r333 [0:5119];
  reg signed [10:0] r334 [0:5119];
  reg signed [10:0] r335 [0:40959];
  reg signed [10:0] r336 [0:40959];
  reg signed [10:0] r337 [0:40959];
  reg signed [10:0] r338 [0:39999];
  reg signed [10:0] r339 [0:39999];
  reg signed [10:0] r340 [0:39999];
  reg signed [23:0] r341 [0:4];
  reg signed [24:0] r342 [0:4];
  reg signed [8:0] r343 [0:7999];
  reg signed [6:0] r344 [0:5];
  reg signed [6:0] r345 [0:5];
  reg signed [6:0] r346 [0:5];
  reg signed [0:0] r347 [0:0];
  reg signed [8:0] r348 [0:8004];
  reg signed [0:0] r349 [0:0];
  reg signed [8:0] r350 [0:8196];
  reg signed [10:0] r351 [0:1023];
  reg signed [10:0] r352 [0:1023];
  reg signed [3:0] r353 [0:5];
  reg signed [3:0] r354 [0:5];
  reg signed [11:0] r355 [0:6143];
  reg signed [3:0] r356 [0:7];
  reg signed [13:0] r357 [0:7];
  reg signed [31:0] r358 [0:8196];
  reg signed [31:0] r359 [0:6143];
  reg signed [31:0] r360 [0:5];
  reg signed [31:0] r361 [0:0];
  reg signed [1:0] r362 [0:0];
  reg signed [0:0] r363 [0:0];
  reg r364 [0:0];
  reg signed [14:0] r366 [0:0];
  reg signed [13:0] r367 [0:0];
  reg signed [8:0] r368 [0:1028];
  reg r369 [0:6143];
  reg signed [12:0] r370 [0:6143];
  reg signed [11:0] r371 [0:6143];
  reg signed [11:0] r372 [0:6143];
  reg signed [8:0] r373 [0:6143];
  reg signed [8:0] r374 [0:6143];
  reg signed [9:0] r375 [0:6143];
  reg signed [9:0] r376 [0:0];
  reg signed [9:0] r377 [0:6143];
  reg signed [9:0] r378 [0:0];
  reg signed [9:0] r379 [0:6143];
  reg signed [9:0] r380 [0:6143];
  reg signed [9:0] r381 [0:0];
  reg signed [9:0] r382 [0:6143];
  reg signed [9:0] r383 [0:0];
  reg signed [9:0] r384 [0:6143];
  reg signed [9:0] r385 [0:6143];
  reg signed [9:0] r386 [0:1023];
  reg signed [9:0] r387 [0:1023];
  reg signed [31:0] r388 [0:6143];
  reg signed [31:0] r389 [0:0];
  reg signed [31:0] r390 [0:0];
  reg signed [31:0] r391 [0:1023];
  reg signed [31:0] r392 [0:1023];
  reg signed [4:0] r393 [0:0];
  reg signed [10:0] r394 [0:1023];
  reg signed [9:0] r395 [0:1023];
  reg signed [9:0] r396 [0:1023];
  reg signed [10:0] r397 [0:6143];
  reg signed [10:0] r398 [0:6143];
  reg signed [13:0] r399 [0:1023];
  reg signed [9:0] r400 [0:6143];
  reg signed [9:0] r401 [0:1023];
  reg signed [10:0] r402 [0:6143];
  reg signed [10:0] r403 [0:6143];
  reg signed [13:0] r404 [0:1023];
  reg signed [14:0] r405 [0:1023];
  reg r406 [0:1023];
  reg signed [9:0] r407 [0:1023];
  reg signed [9:0] r408 [0:1023];
  reg signed [9:0] r409 [0:0];
  reg signed [9:0] r410 [0:1023];
  reg signed [9:0] r411 [0:1023];
  reg signed [9:0] r412 [0:6143];
  reg signed [9:0] r413 [0:1023];
  reg signed [9:0] r414 [0:1023];
  reg signed [31:0] r415 [0:6143];
  reg signed [31:0] r416 [0:0];
  reg signed [31:0] r417 [0:0];
  reg signed [31:0] r418 [0:1023];
  reg signed [31:0] r419 [0:1023];
  reg signed [4:0] r420 [0:0];
  reg signed [10:0] r421 [0:1023];
  reg signed [9:0] r422 [0:1023];
  reg signed [9:0] r423 [0:1023];
  reg signed [10:0] r424 [0:6143];
  reg signed [10:0] r425 [0:6143];
  reg signed [13:0] r426 [0:1023];
  reg signed [9:0] r427 [0:6143];
  reg signed [9:0] r428 [0:1023];
  reg signed [10:0] r429 [0:6143];
  reg signed [10:0] r430 [0:6143];
  reg signed [13:0] r431 [0:1023];
  reg signed [14:0] r432 [0:1023];
  reg r433 [0:1023];
  reg signed [9:0] r434 [0:1023];
  reg signed [9:0] r435 [0:1023];
  reg signed [9:0] r436 [0:0];
  reg signed [9:0] r437 [0:1023];
  reg signed [9:0] r438 [0:1023];
  reg signed [10:0] r439 [0:1023];
  reg signed [10:0] r440 [0:8191];
  reg signed [10:0] r441 [0:8191];
  reg signed [10:0] r442 [0:8191];
  reg signed [10:0] r443 [0:7999];
  reg signed [10:0] r444 [0:7999];
  reg signed [10:0] r445 [0:7999];
  reg signed [10:0] r446 [0:7999];
  reg signed [9:0] r447 [0:7999];
  reg signed [7:0] r448 [0:0];
  reg signed [9:0] r449 [0:7999];
  reg signed [7:0] r450 [0:0];
  reg signed [7:0] r451 [0:7999];
  reg signed [12:0] r452 [0:3999];
  reg signed [13:0] r453 [0:3999];
  reg signed [13:0] r454 [0:3999];
  reg signed [13:0] r455 [0:3999];
  reg signed [7:0] r456 [0:3999];
  reg signed [8:0] r457 [0:3999];
  reg signed [5:0] r458 [0:79];
  reg signed [5:0] r459 [0:79];
  reg signed [5:0] r460 [0:79];
  reg signed [0:0] r461 [0:0];
  reg signed [8:0] r462 [0:4014];
  reg signed [0:0] r463 [0:0];
  reg signed [8:0] r464 [0:4110];
  reg signed [10:0] r465 [0:1023];
  reg signed [10:0] r466 [0:1023];
  reg signed [4:0] r467 [0:15];
  reg signed [4:0] r468 [0:15];
  reg signed [11:0] r469 [0:16383];
  reg signed [2:0] r470 [0:3];
  reg signed [12:0] r471 [0:3];
  reg signed [31:0] r472 [0:4110];
  reg signed [31:0] r473 [0:16383];
  reg signed [31:0] r474 [0:79];
  reg signed [31:0] r475 [0:0];
  reg signed [1:0] r476 [0:0];
  reg signed [0:0] r477 [0:0];
  reg r478 [0:0];
  reg signed [13:0] r480 [0:0];
  reg signed [12:0] r481 [0:0];
  reg signed [8:0] r482 [0:1038];
  reg r483 [0:16383];
  reg signed [12:0] r484 [0:16383];
  reg signed [11:0] r485 [0:16383];
  reg signed [11:0] r486 [0:16383];
  reg signed [8:0] r487 [0:16383];
  reg signed [8:0] r488 [0:16383];
  reg signed [9:0] r489 [0:81919];
  reg signed [9:0] r490 [0:0];
  reg signed [9:0] r491 [0:81919];
  reg signed [9:0] r492 [0:0];
  reg signed [9:0] r493 [0:81919];
  reg signed [9:0] r494 [0:81919];
  reg signed [9:0] r495 [0:0];
  reg signed [9:0] r496 [0:81919];
  reg signed [9:0] r497 [0:0];
  reg signed [9:0] r498 [0:81919];
  reg signed [9:0] r499 [0:81919];
  reg signed [9:0] r500 [0:5119];
  reg signed [9:0] r501 [0:5119];
  reg signed [31:0] r502 [0:81919];
  reg signed [31:0] r503 [0:0];
  reg signed [31:0] r504 [0:0];
  reg signed [31:0] r505 [0:5119];
  reg signed [31:0] r506 [0:5119];
  reg signed [4:0] r507 [0:0];
  reg signed [10:0] r508 [0:5119];
  reg signed [9:0] r509 [0:5119];
  reg signed [9:0] r510 [0:5119];
  reg signed [10:0] r511 [0:81919];
  reg signed [10:0] r512 [0:81919];
  reg signed [14:0] r513 [0:5119];
  reg signed [9:0] r514 [0:81919];
  reg signed [9:0] r515 [0:5119];
  reg signed [10:0] r516 [0:81919];
  reg signed [10:0] r517 [0:81919];
  reg signed [14:0] r518 [0:5119];
  reg signed [15:0] r519 [0:5119];
  reg r520 [0:5119];
  reg signed [9:0] r521 [0:5119];
  reg signed [9:0] r522 [0:5119];
  reg signed [9:0] r523 [0:0];
  reg signed [9:0] r524 [0:5119];
  reg signed [9:0] r525 [0:5119];
  reg signed [9:0] r526 [0:81919];
  reg signed [9:0] r527 [0:5119];
  reg signed [9:0] r528 [0:5119];
  reg signed [31:0] r529 [0:81919];
  reg signed [31:0] r530 [0:0];
  reg signed [31:0] r531 [0:0];
  reg signed [31:0] r532 [0:5119];
  reg signed [31:0] r533 [0:5119];
  reg signed [4:0] r534 [0:0];
  reg signed [10:0] r535 [0:5119];
  reg signed [9:0] r536 [0:5119];
  reg signed [9:0] r537 [0:5119];
  reg signed [10:0] r538 [0:81919];
  reg signed [10:0] r539 [0:81919];
  reg signed [14:0] r540 [0:5119];
  reg signed [9:0] r541 [0:81919];
  reg signed [9:0] r542 [0:5119];
  reg signed [10:0] r543 [0:81919];
  reg signed [10:0] r544 [0:81919];
  reg signed [14:0] r545 [0:5119];
  reg signed [15:0] r546 [0:5119];
  reg r547 [0:5119];
  reg signed [9:0] r548 [0:5119];
  reg signed [9:0] r549 [0:5119];
  reg signed [9:0] r550 [0:0];
  reg signed [9:0] r551 [0:5119];
  reg signed [9:0] r552 [0:5119];
  reg signed [10:0] r553 [0:5119];
  reg signed [10:0] r554 [0:20479];
  reg signed [10:0] r555 [0:20479];
  reg signed [10:0] r556 [0:20479];
  reg signed [10:0] r557 [0:19999];
  reg signed [10:0] r558 [0:19999];
  reg signed [10:0] r559 [0:19999];
  reg signed [22:0] r560 [0:4];
  reg signed [24:0] r562 [0:4];
  reg signed [8:0] r563 [0:3999];
  reg signed [6:0] r564 [0:5];
  reg signed [6:0] r565 [0:5];
  reg signed [6:0] r566 [0:5];
  reg signed [0:0] r567 [0:0];
  reg signed [8:0] r568 [0:4004];
  reg signed [0:0] r569 [0:0];
  reg signed [8:0] r570 [0:4100];
  reg signed [10:0] r571 [0:1023];
  reg signed [10:0] r572 [0:1023];
  reg signed [3:0] r573 [0:5];
  reg signed [3:0] r574 [0:5];
  reg signed [11:0] r575 [0:6143];
  reg signed [2:0] r576 [0:3];
  reg signed [12:0] r577 [0:3];
  reg signed [31:0] r578 [0:4100];
  reg signed [31:0] r579 [0:6143];
  reg signed [31:0] r580 [0:5];
  reg signed [31:0] r581 [0:0];
  reg signed [1:0] r582 [0:0];
  reg signed [0:0] r583 [0:0];
  reg r584 [0:0];
  reg signed [13:0] r586 [0:0];
  reg signed [12:0] r587 [0:0];
  reg signed [8:0] r588 [0:1028];
  reg r589 [0:6143];
  reg signed [12:0] r590 [0:6143];
  reg signed [11:0] r591 [0:6143];
  reg signed [11:0] r592 [0:6143];
  reg signed [8:0] r593 [0:6143];
  reg signed [8:0] r594 [0:6143];
  reg signed [9:0] r595 [0:6143];
  reg signed [9:0] r596 [0:0];
  reg signed [9:0] r597 [0:6143];
  reg signed [9:0] r598 [0:0];
  reg signed [9:0] r599 [0:6143];
  reg signed [9:0] r600 [0:6143];
  reg signed [9:0] r601 [0:0];
  reg signed [9:0] r602 [0:6143];
  reg signed [9:0] r603 [0:0];
  reg signed [9:0] r604 [0:6143];
  reg signed [9:0] r605 [0:6143];
  reg signed [9:0] r606 [0:1023];
  reg signed [9:0] r607 [0:1023];
  reg signed [31:0] r608 [0:6143];
  reg signed [31:0] r609 [0:0];
  reg signed [31:0] r610 [0:0];
  reg signed [31:0] r611 [0:1023];
  reg signed [31:0] r612 [0:1023];
  reg signed [4:0] r613 [0:0];
  reg signed [10:0] r614 [0:1023];
  reg signed [9:0] r615 [0:1023];
  reg signed [9:0] r616 [0:1023];
  reg signed [10:0] r617 [0:6143];
  reg signed [10:0] r618 [0:6143];
  reg signed [13:0] r619 [0:1023];
  reg signed [9:0] r620 [0:6143];
  reg signed [9:0] r621 [0:1023];
  reg signed [10:0] r622 [0:6143];
  reg signed [10:0] r623 [0:6143];
  reg signed [13:0] r624 [0:1023];
  reg signed [14:0] r625 [0:1023];
  reg r626 [0:1023];
  reg signed [9:0] r627 [0:1023];
  reg signed [9:0] r628 [0:1023];
  reg signed [9:0] r629 [0:0];
  reg signed [9:0] r630 [0:1023];
  reg signed [9:0] r631 [0:1023];
  reg signed [9:0] r632 [0:6143];
  reg signed [9:0] r633 [0:1023];
  reg signed [9:0] r634 [0:1023];
  reg signed [31:0] r635 [0:6143];
  reg signed [31:0] r636 [0:0];
  reg signed [31:0] r637 [0:0];
  reg signed [31:0] r638 [0:1023];
  reg signed [31:0] r639 [0:1023];
  reg signed [4:0] r640 [0:0];
  reg signed [10:0] r641 [0:1023];
  reg signed [9:0] r642 [0:1023];
  reg signed [9:0] r643 [0:1023];
  reg signed [10:0] r644 [0:6143];
  reg signed [10:0] r645 [0:6143];
  reg signed [13:0] r646 [0:1023];
  reg signed [9:0] r647 [0:6143];
  reg signed [9:0] r648 [0:1023];
  reg signed [10:0] r649 [0:6143];
  reg signed [10:0] r650 [0:6143];
  reg signed [13:0] r651 [0:1023];
  reg signed [14:0] r652 [0:1023];
  reg r653 [0:1023];
  reg signed [9:0] r654 [0:1023];
  reg signed [9:0] r655 [0:1023];
  reg signed [9:0] r656 [0:0];
  reg signed [9:0] r657 [0:1023];
  reg signed [9:0] r658 [0:1023];
  reg signed [10:0] r659 [0:1023];
  reg signed [10:0] r660 [0:4095];
  reg signed [10:0] r661 [0:4095];
  reg signed [10:0] r662 [0:4095];
  reg signed [10:0] r663 [0:3999];
  reg signed [10:0] r664 [0:3999];
  reg signed [10:0] r665 [0:3999];
  reg signed [10:0] r666 [0:3999];
  reg signed [9:0] r667 [0:3999];
  reg signed [7:0] r668 [0:0];
  reg signed [9:0] r669 [0:3999];
  reg signed [7:0] r670 [0:0];
  reg signed [7:0] r671 [0:3999];
  reg signed [11:0] r672 [0:1999];
  reg signed [12:0] r673 [0:1999];
  reg signed [12:0] r674 [0:1999];
  reg signed [12:0] r675 [0:1999];
  reg signed [7:0] r676 [0:1999];
  reg signed [8:0] r677 [0:1999];
  reg signed [5:0] r678 [0:79];
  reg signed [5:0] r679 [0:79];
  reg signed [5:0] r680 [0:79];
  reg signed [0:0] r681 [0:0];
  reg signed [8:0] r682 [0:2014];
  reg signed [0:0] r683 [0:0];
  reg signed [8:0] r684 [0:2062];
  reg signed [10:0] r685 [0:1023];
  reg signed [10:0] r686 [0:1023];
  reg signed [4:0] r687 [0:15];
  reg signed [4:0] r688 [0:15];
  reg signed [11:0] r689 [0:16383];
  reg signed [1:0] r690 [0:1];
  reg signed [11:0] r691 [0:1];
  reg signed [31:0] r692 [0:2062];
  reg signed [31:0] r693 [0:16383];
  reg signed [31:0] r694 [0:79];
  reg signed [31:0] r695 [0:0];
  reg signed [1:0] r696 [0:0];
  reg signed [0:0] r697 [0:0];
  reg r698 [0:0];
  reg signed [12:0] r700 [0:0];
  reg signed [11:0] r701 [0:0];
  reg signed [8:0] r702 [0:1038];
  reg r703 [0:16383];
  reg signed [12:0] r704 [0:16383];
  reg signed [11:0] r705 [0:16383];
  reg signed [11:0] r706 [0:16383];
  reg signed [8:0] r707 [0:16383];
  reg signed [8:0] r708 [0:16383];
  reg signed [9:0] r709 [0:81919];
  reg signed [9:0] r710 [0:0];
  reg signed [9:0] r711 [0:81919];
  reg signed [9:0] r712 [0:0];
  reg signed [9:0] r713 [0:81919];
  reg signed [9:0] r714 [0:81919];
  reg signed [9:0] r715 [0:0];
  reg signed [9:0] r716 [0:81919];
  reg signed [9:0] r717 [0:0];
  reg signed [9:0] r718 [0:81919];
  reg signed [9:0] r719 [0:81919];
  reg signed [9:0] r720 [0:5119];
  reg signed [9:0] r721 [0:5119];
  reg signed [31:0] r722 [0:81919];
  reg signed [31:0] r723 [0:0];
  reg signed [31:0] r724 [0:0];
  reg signed [31:0] r725 [0:5119];
  reg signed [31:0] r726 [0:5119];
  reg signed [4:0] r727 [0:0];
  reg signed [10:0] r728 [0:5119];
  reg signed [9:0] r729 [0:5119];
  reg signed [9:0] r730 [0:5119];
  reg signed [10:0] r731 [0:81919];
  reg signed [10:0] r732 [0:81919];
  reg signed [14:0] r733 [0:5119];
  reg signed [9:0] r734 [0:81919];
  reg signed [9:0] r735 [0:5119];
  reg signed [10:0] r736 [0:81919];
  reg signed [10:0] r737 [0:81919];
  reg signed [14:0] r738 [0:5119];
  reg signed [15:0] r739 [0:5119];
  reg r740 [0:5119];
  reg signed [9:0] r741 [0:5119];
  reg signed [9:0] r742 [0:5119];
  reg signed [9:0] r743 [0:0];
  reg signed [9:0] r744 [0:5119];
  reg signed [9:0] r745 [0:5119];
  reg signed [9:0] r746 [0:81919];
  reg signed [9:0] r747 [0:5119];
  reg signed [9:0] r748 [0:5119];
  reg signed [31:0] r749 [0:81919];
  reg signed [31:0] r750 [0:0];
  reg signed [31:0] r751 [0:0];
  reg signed [31:0] r752 [0:5119];
  reg signed [31:0] r753 [0:5119];
  reg signed [4:0] r754 [0:0];
  reg signed [10:0] r755 [0:5119];
  reg signed [9:0] r756 [0:5119];
  reg signed [9:0] r757 [0:5119];
  reg signed [10:0] r758 [0:81919];
  reg signed [10:0] r759 [0:81919];
  reg signed [14:0] r760 [0:5119];
  reg signed [9:0] r761 [0:81919];
  reg signed [9:0] r762 [0:5119];
  reg signed [10:0] r763 [0:81919];
  reg signed [10:0] r764 [0:81919];
  reg signed [14:0] r765 [0:5119];
  reg signed [15:0] r766 [0:5119];
  reg r767 [0:5119];
  reg signed [9:0] r768 [0:5119];
  reg signed [9:0] r769 [0:5119];
  reg signed [9:0] r770 [0:0];
  reg signed [9:0] r771 [0:5119];
  reg signed [9:0] r772 [0:5119];
  reg signed [10:0] r773 [0:5119];
  reg signed [10:0] r774 [0:10239];
  reg signed [10:0] r775 [0:10239];
  reg signed [10:0] r776 [0:10239];
  reg signed [10:0] r777 [0:9999];
  reg signed [10:0] r778 [0:9999];
  reg signed [10:0] r779 [0:9999];
  reg signed [21:0] r780 [0:4];
  reg signed [24:0] r782 [0:4];
  reg signed [8:0] r783 [0:1999];
  reg signed [6:0] r784 [0:5];
  reg signed [6:0] r785 [0:5];
  reg signed [6:0] r786 [0:5];
  reg signed [0:0] r787 [0:0];
  reg signed [8:0] r788 [0:2004];
  reg signed [0:0] r789 [0:0];
  reg signed [8:0] r790 [0:2052];
  reg signed [10:0] r791 [0:1023];
  reg signed [10:0] r792 [0:1023];
  reg signed [3:0] r793 [0:5];
  reg signed [3:0] r794 [0:5];
  reg signed [11:0] r795 [0:6143];
  reg signed [1:0] r796 [0:1];
  reg signed [11:0] r797 [0:1];
  reg signed [31:0] r798 [0:2052];
  reg signed [31:0] r799 [0:6143];
  reg signed [31:0] r800 [0:5];
  reg signed [31:0] r801 [0:0];
  reg signed [1:0] r802 [0:0];
  reg signed [0:0] r803 [0:0];
  reg r804 [0:0];
  reg signed [12:0] r806 [0:0];
  reg signed [11:0] r807 [0:0];
  reg signed [8:0] r808 [0:1028];
  reg r809 [0:6143];
  reg signed [12:0] r810 [0:6143];
  reg signed [11:0] r811 [0:6143];
  reg signed [11:0] r812 [0:6143];
  reg signed [8:0] r813 [0:6143];
  reg signed [8:0] r814 [0:6143];
  reg signed [9:0] r815 [0:6143];
  reg signed [9:0] r816 [0:0];
  reg signed [9:0] r817 [0:6143];
  reg signed [9:0] r818 [0:0];
  reg signed [9:0] r819 [0:6143];
  reg signed [9:0] r820 [0:6143];
  reg signed [9:0] r821 [0:0];
  reg signed [9:0] r822 [0:6143];
  reg signed [9:0] r823 [0:0];
  reg signed [9:0] r824 [0:6143];
  reg signed [9:0] r825 [0:6143];
  reg signed [9:0] r826 [0:1023];
  reg signed [9:0] r827 [0:1023];
  reg signed [31:0] r828 [0:6143];
  reg signed [31:0] r829 [0:0];
  reg signed [31:0] r830 [0:0];
  reg signed [31:0] r831 [0:1023];
  reg signed [31:0] r832 [0:1023];
  reg signed [4:0] r833 [0:0];
  reg signed [10:0] r834 [0:1023];
  reg signed [9:0] r835 [0:1023];
  reg signed [9:0] r836 [0:1023];
  reg signed [10:0] r837 [0:6143];
  reg signed [10:0] r838 [0:6143];
  reg signed [13:0] r839 [0:1023];
  reg signed [9:0] r840 [0:6143];
  reg signed [9:0] r841 [0:1023];
  reg signed [10:0] r842 [0:6143];
  reg signed [10:0] r843 [0:6143];
  reg signed [13:0] r844 [0:1023];
  reg signed [14:0] r845 [0:1023];
  reg r846 [0:1023];
  reg signed [9:0] r847 [0:1023];
  reg signed [9:0] r848 [0:1023];
  reg signed [9:0] r849 [0:0];
  reg signed [9:0] r850 [0:1023];
  reg signed [9:0] r851 [0:1023];
  reg signed [9:0] r852 [0:6143];
  reg signed [9:0] r853 [0:1023];
  reg signed [9:0] r854 [0:1023];
  reg signed [31:0] r855 [0:6143];
  reg signed [31:0] r856 [0:0];
  reg signed [31:0] r857 [0:0];
  reg signed [31:0] r858 [0:1023];
  reg signed [31:0] r859 [0:1023];
  reg signed [4:0] r860 [0:0];
  reg signed [10:0] r861 [0:1023];
  reg signed [9:0] r862 [0:1023];
  reg signed [9:0] r863 [0:1023];
  reg signed [10:0] r864 [0:6143];
  reg signed [10:0] r865 [0:6143];
  reg signed [13:0] r866 [0:1023];
  reg signed [9:0] r867 [0:6143];
  reg signed [9:0] r868 [0:1023];
  reg signed [10:0] r869 [0:6143];
  reg signed [10:0] r870 [0:6143];
  reg signed [13:0] r871 [0:1023];
  reg signed [14:0] r872 [0:1023];
  reg r873 [0:1023];
  reg signed [9:0] r874 [0:1023];
  reg signed [9:0] r875 [0:1023];
  reg signed [9:0] r876 [0:0];
  reg signed [9:0] r877 [0:1023];
  reg signed [9:0] r878 [0:1023];
  reg signed [10:0] r879 [0:1023];
  reg signed [10:0] r880 [0:2047];
  reg signed [10:0] r881 [0:2047];
  reg signed [10:0] r882 [0:2047];
  reg signed [10:0] r883 [0:1999];
  reg signed [10:0] r884 [0:1999];
  reg signed [10:0] r885 [0:1999];
  reg signed [10:0] r886 [0:1999];
  reg signed [9:0] r887 [0:1999];
  reg signed [7:0] r888 [0:0];
  reg signed [9:0] r889 [0:1999];
  reg signed [7:0] r890 [0:0];
  reg signed [7:0] r891 [0:1999];
  reg signed [10:0] r892 [0:999];
  reg signed [11:0] r893 [0:999];
  reg signed [11:0] r894 [0:999];
  reg signed [11:0] r895 [0:999];
  reg signed [7:0] r896 [0:999];
  reg signed [8:0] r897 [0:999];
  reg signed [5:0] r898 [0:79];
  reg signed [5:0] r899 [0:79];
  reg signed [5:0] r900 [0:79];
  reg signed [0:0] r901 [0:0];
  reg signed [8:0] r902 [0:1014];
  reg signed [10:0] r903 [0:999];
  reg signed [10:0] r904 [0:999];
  reg signed [4:0] r905 [0:15];
  reg signed [4:0] r906 [0:15];
  reg signed [10:0] r907 [0:15999];
  reg r908 [0:15999];
  reg signed [11:0] r910 [0:15999];
  reg signed [10:0] r911 [0:15999];
  reg signed [10:0] r912 [0:15999];
  reg signed [8:0] r913 [0:15999];
  reg signed [8:0] r914 [0:15999];
  reg signed [9:0] r915 [0:79999];
  reg signed [9:0] r916 [0:0];
  reg signed [9:0] r917 [0:79999];
  reg signed [9:0] r918 [0:0];
  reg signed [9:0] r919 [0:79999];
  reg signed [9:0] r920 [0:79999];
  reg signed [9:0] r921 [0:0];
  reg signed [9:0] r922 [0:79999];
  reg signed [9:0] r923 [0:0];
  reg signed [9:0] r924 [0:79999];
  reg signed [9:0] r925 [0:79999];
  reg signed [9:0] r926 [0:4999];
  reg signed [9:0] r927 [0:4999];
  reg signed [31:0] r928 [0:79999];
  reg signed [31:0] r929 [0:0];
  reg signed [31:0] r930 [0:0];
  reg signed [31:0] r931 [0:4999];
  reg signed [31:0] r932 [0:4999];
  reg signed [4:0] r933 [0:0];
  reg signed [10:0] r934 [0:4999];
  reg signed [9:0] r935 [0:4999];
  reg signed [9:0] r936 [0:4999];
  reg signed [10:0] r937 [0:79999];
  reg signed [10:0] r938 [0:79999];
  reg signed [14:0] r939 [0:4999];
  reg signed [9:0] r940 [0:79999];
  reg signed [9:0] r941 [0:4999];
  reg signed [10:0] r942 [0:79999];
  reg signed [10:0] r943 [0:79999];
  reg signed [14:0] r944 [0:4999];
  reg signed [15:0] r945 [0:4999];
  reg r946 [0:4999];
  reg signed [9:0] r947 [0:4999];
  reg signed [9:0] r948 [0:4999];
  reg signed [9:0] r949 [0:0];
  reg signed [9:0] r950 [0:4999];
  reg signed [9:0] r951 [0:4999];
  reg signed [9:0] r952 [0:79999];
  reg signed [9:0] r953 [0:4999];
  reg signed [9:0] r954 [0:4999];
  reg signed [31:0] r955 [0:79999];
  reg signed [31:0] r956 [0:0];
  reg signed [31:0] r957 [0:0];
  reg signed [31:0] r958 [0:4999];
  reg signed [31:0] r959 [0:4999];
  reg signed [4:0] r960 [0:0];
  reg signed [10:0] r961 [0:4999];
  reg signed [9:0] r962 [0:4999];
  reg signed [9:0] r963 [0:4999];
  reg signed [10:0] r964 [0:79999];
  reg signed [10:0] r965 [0:79999];
  reg signed [14:0] r966 [0:4999];
  reg signed [9:0] r967 [0:79999];
  reg signed [9:0] r968 [0:4999];
  reg signed [10:0] r969 [0:79999];
  reg signed [10:0] r970 [0:79999];
  reg signed [14:0] r971 [0:4999];
  reg signed [15:0] r972 [0:4999];
  reg r973 [0:4999];
  reg signed [9:0] r974 [0:4999];
  reg signed [9:0] r975 [0:4999];
  reg signed [9:0] r976 [0:0];
  reg signed [9:0] r977 [0:4999];
  reg signed [9:0] r978 [0:4999];
  reg signed [10:0] r979 [0:4999];
  reg signed [10:0] r980 [0:4999];
  reg signed [10:0] r981 [0:4999];
  reg signed [20:0] r982 [0:4];
  reg signed [24:0] r984 [0:4];
  reg signed [8:0] r985 [0:999];
  reg signed [6:0] r986 [0:5];
  reg signed [6:0] r987 [0:5];
  reg signed [6:0] r988 [0:5];
  reg signed [0:0] r989 [0:0];
  reg signed [8:0] r990 [0:1004];
  reg signed [10:0] r991 [0:999];
  reg signed [10:0] r992 [0:999];
  reg signed [3:0] r993 [0:5];
  reg signed [3:0] r994 [0:5];
  reg signed [10:0] r995 [0:5999];
  reg r996 [0:5999];
  reg signed [11:0] r998 [0:5999];
  reg signed [10:0] r999 [0:5999];
  reg signed [10:0] r1000 [0:5999];
  reg signed [8:0] r1001 [0:5999];
  reg signed [8:0] r1002 [0:5999];
  reg signed [9:0] r1003 [0:5999];
  reg signed [9:0] r1004 [0:0];
  reg signed [9:0] r1005 [0:5999];
  reg signed [9:0] r1006 [0:0];
  reg signed [9:0] r1007 [0:5999];
  reg signed [9:0] r1008 [0:5999];
  reg signed [9:0] r1009 [0:0];
  reg signed [9:0] r1010 [0:5999];
  reg signed [9:0] r1011 [0:0];
  reg signed [9:0] r1012 [0:5999];
  reg signed [9:0] r1013 [0:5999];
  reg signed [9:0] r1014 [0:999];
  reg signed [9:0] r1015 [0:999];
  reg signed [31:0] r1016 [0:5999];
  reg signed [31:0] r1017 [0:0];
  reg signed [31:0] r1018 [0:0];
  reg signed [31:0] r1019 [0:999];
  reg signed [31:0] r1020 [0:999];
  reg signed [4:0] r1021 [0:0];
  reg signed [10:0] r1022 [0:999];
  reg signed [9:0] r1023 [0:999];
  reg signed [9:0] r1024 [0:999];
  reg signed [10:0] r1025 [0:5999];
  reg signed [10:0] r1026 [0:5999];
  reg signed [13:0] r1027 [0:999];
  reg signed [9:0] r1028 [0:5999];
  reg signed [9:0] r1029 [0:999];
  reg signed [10:0] r1030 [0:5999];
  reg signed [10:0] r1031 [0:5999];
  reg signed [13:0] r1032 [0:999];
  reg signed [14:0] r1033 [0:999];
  reg r1034 [0:999];
  reg signed [9:0] r1035 [0:999];
  reg signed [9:0] r1036 [0:999];
  reg signed [9:0] r1037 [0:0];
  reg signed [9:0] r1038 [0:999];
  reg signed [9:0] r1039 [0:999];
  reg signed [9:0] r1040 [0:5999];
  reg signed [9:0] r1041 [0:999];
  reg signed [9:0] r1042 [0:999];
  reg signed [31:0] r1043 [0:5999];
  reg signed [31:0] r1044 [0:0];
  reg signed [31:0] r1045 [0:0];
  reg signed [31:0] r1046 [0:999];
  reg signed [31:0] r1047 [0:999];
  reg signed [4:0] r1048 [0:0];
  reg signed [10:0] r1049 [0:999];
  reg signed [9:0] r1050 [0:999];
  reg signed [9:0] r1051 [0:999];
  reg signed [10:0] r1052 [0:5999];
  reg signed [10:0] r1053 [0:5999];
  reg signed [13:0] r1054 [0:999];
  reg signed [9:0] r1055 [0:5999];
  reg signed [9:0] r1056 [0:999];
  reg signed [10:0] r1057 [0:5999];
  reg signed [10:0] r1058 [0:5999];
  reg signed [13:0] r1059 [0:999];
  reg signed [14:0] r1060 [0:999];
  reg r1061 [0:999];
  reg signed [9:0] r1062 [0:999];
  reg signed [9:0] r1063 [0:999];
  reg signed [9:0] r1064 [0:0];
  reg signed [9:0] r1065 [0:999];
  reg signed [9:0] r1066 [0:999];
  reg signed [10:0] r1067 [0:999];
  reg signed [10:0] r1068 [0:999];
  reg signed [10:0] r1069 [0:999];
  reg signed [10:0] r1070 [0:999];
  reg signed [9:0] r1071 [0:999];
  reg signed [7:0] r1072 [0:0];
  reg signed [9:0] r1073 [0:999];
  reg signed [7:0] r1074 [0:0];
  reg signed [7:0] r1075 [0:999];
  reg signed [9:0] r1076 [0:499];
  reg signed [10:0] r1077 [0:499];
  reg signed [10:0] r1078 [0:499];
  reg signed [10:0] r1079 [0:499];
  reg signed [7:0] r1080 [0:499];
  reg signed [8:0] r1081 [0:499];
  reg signed [5:0] r1082 [0:79];
  reg signed [5:0] r1083 [0:79];
  reg signed [5:0] r1084 [0:79];
  reg signed [0:0] r1085 [0:0];
  reg signed [8:0] r1086 [0:514];
  reg signed [9:0] r1087 [0:499];
  reg signed [9:0] r1088 [0:499];
  reg signed [4:0] r1089 [0:15];
  reg signed [4:0] r1090 [0:15];
  reg signed [10:0] r1091 [0:7999];
  reg r1092 [0:7999];
  reg signed [11:0] r1094 [0:7999];
  reg signed [10:0] r1095 [0:7999];
  reg signed [10:0] r1096 [0:7999];
  reg signed [8:0] r1097 [0:7999];
  reg signed [8:0] r1098 [0:7999];
  reg signed [9:0] r1099 [0:39999];
  reg signed [9:0] r1100 [0:0];
  reg signed [9:0] r1101 [0:39999];
  reg signed [9:0] r1102 [0:0];
  reg signed [9:0] r1103 [0:39999];
  reg signed [9:0] r1104 [0:39999];
  reg signed [9:0] r1105 [0:0];
  reg signed [9:0] r1106 [0:39999];
  reg signed [9:0] r1107 [0:0];
  reg signed [9:0] r1108 [0:39999];
  reg signed [9:0] r1109 [0:39999];
  reg signed [9:0] r1110 [0:2499];
  reg signed [9:0] r1111 [0:2499];
  reg signed [31:0] r1112 [0:39999];
  reg signed [31:0] r1113 [0:0];
  reg signed [31:0] r1114 [0:0];
  reg signed [31:0] r1115 [0:2499];
  reg signed [31:0] r1116 [0:2499];
  reg signed [4:0] r1117 [0:0];
  reg signed [10:0] r1118 [0:2499];
  reg signed [9:0] r1119 [0:2499];
  reg signed [9:0] r1120 [0:2499];
  reg signed [10:0] r1121 [0:39999];
  reg signed [10:0] r1122 [0:39999];
  reg signed [14:0] r1123 [0:2499];
  reg signed [9:0] r1124 [0:39999];
  reg signed [9:0] r1125 [0:2499];
  reg signed [10:0] r1126 [0:39999];
  reg signed [10:0] r1127 [0:39999];
  reg signed [14:0] r1128 [0:2499];
  reg signed [15:0] r1129 [0:2499];
  reg r1130 [0:2499];
  reg signed [9:0] r1131 [0:2499];
  reg signed [9:0] r1132 [0:2499];
  reg signed [9:0] r1133 [0:0];
  reg signed [9:0] r1134 [0:2499];
  reg signed [9:0] r1135 [0:2499];
  reg signed [9:0] r1136 [0:39999];
  reg signed [9:0] r1137 [0:2499];
  reg signed [9:0] r1138 [0:2499];
  reg signed [31:0] r1139 [0:39999];
  reg signed [31:0] r1140 [0:0];
  reg signed [31:0] r1141 [0:0];
  reg signed [31:0] r1142 [0:2499];
  reg signed [31:0] r1143 [0:2499];
  reg signed [4:0] r1144 [0:0];
  reg signed [10:0] r1145 [0:2499];
  reg signed [9:0] r1146 [0:2499];
  reg signed [9:0] r1147 [0:2499];
  reg signed [10:0] r1148 [0:39999];
  reg signed [10:0] r1149 [0:39999];
  reg signed [14:0] r1150 [0:2499];
  reg signed [9:0] r1151 [0:39999];
  reg signed [9:0] r1152 [0:2499];
  reg signed [10:0] r1153 [0:39999];
  reg signed [10:0] r1154 [0:39999];
  reg signed [14:0] r1155 [0:2499];
  reg signed [15:0] r1156 [0:2499];
  reg r1157 [0:2499];
  reg signed [9:0] r1158 [0:2499];
  reg signed [9:0] r1159 [0:2499];
  reg signed [9:0] r1160 [0:0];
  reg signed [9:0] r1161 [0:2499];
  reg signed [9:0] r1162 [0:2499];
  reg signed [10:0] r1163 [0:2499];
  reg signed [10:0] r1164 [0:2499];
  reg signed [10:0] r1165 [0:2499];
  reg signed [19:0] r1166 [0:4];
  reg signed [24:0] r1168 [0:4];
  reg signed [24:0] r1169 [0:29];
  reg signed [0:0] r1170 [0:29];
  reg signed [0:0] r1171 [0:29];
  reg signed [24:0] r1172 [0:29];
  reg signed [2:0] r1173 [0:29];
  reg r1174 [0:29];
  reg signed [0:0] r1175 [0:29];
  reg signed [0:0] r1176 [0:29];
  reg signed [24:0] r1177 [0:29];
  reg signed [2:0] r1178 [0:29];
  reg signed [2:0] r1179 [0:29];
  reg signed [2:0] r1180 [0:29];
  reg signed [21:0] r1181 [0:29];
  reg r1182 [0:29];
  reg signed [21:0] r1183 [0:29];
  reg signed [2:0] r1184 [0:29];
  reg r1185 [0:29];
  reg signed [0:0] r1186 [0:29];
  reg signed [0:0] r1187 [0:29];
  reg signed [24:0] r1188 [0:29];
  reg signed [3:0] r1189 [0:29];
  reg signed [3:0] r1190 [0:29];
  reg signed [3:0] r1191 [0:29];
  reg signed [20:0] r1192 [0:29];
  reg r1193 [0:29];
  reg signed [21:0] r1194 [0:29];
  reg signed [0:0] r1195 [0:29];
  reg r1196 [0:29];
  reg signed [22:0] r1197 [0:29];
  reg r1198 [0:29];
  reg signed [21:0] r1199 [0:29];
  reg r1200 [0:29];
  reg signed [21:0] r1201 [0:29];
  reg r1202 [0:29];
  reg signed [21:0] r1203 [0:29];
  reg signed [7:0] r1204 [0:0];
  reg signed [21:0] r1205 [0:29];
  reg signed [7:0] r1206 [0:0];
  reg signed [7:0] r1207 [0:29];
  reg signed [8:0] r1208 [0:29];
  reg signed [8:0] r1209 [0:29];
  reg signed [8:0] r1210 [0:29];
  reg signed [8:0] r1211 [0:29];
  reg signed [5:0] r1212 [0:299];
  reg signed [5:0] r1213 [0:299];
  reg signed [5:0] r1214 [0:299];
  reg signed [9:0] r1215 [0:299];
  reg signed [9:0] r1216 [0:0];
  reg signed [9:0] r1217 [0:299];
  reg signed [9:0] r1218 [0:0];
  reg signed [9:0] r1219 [0:299];
  reg signed [5:0] r1220 [0:299];
  reg signed [8:0] r1221 [0:299];
  reg signed [9:0] r1222 [0:0];
  reg signed [9:0] r1223 [0:299];
  reg signed [9:0] r1224 [0:0];
  reg signed [9:0] r1225 [0:299];
  reg signed [9:0] r1226 [0:599];
  reg signed [0:0] r1227 [0:9];
  reg signed [0:0] r1228 [0:9];
  reg signed [9:0] r1229 [0:609];
  reg signed [9:0] r1230 [0:609];
  reg signed [9:0] r1231 [0:9];
  reg signed [9:0] r1233 [0:9];
  reg signed [31:0] r1234 [0:609];
  reg signed [31:0] r1235 [0:0];
  reg signed [31:0] r1236 [0:0];
  reg signed [31:0] r1237 [0:9];
  reg signed [31:0] r1238 [0:9];
  reg signed [4:0] r1239 [0:0];
  reg signed [10:0] r1240 [0:9];
  reg signed [9:0] r1241 [0:9];
  reg signed [9:0] r1242 [0:9];
  reg signed [10:0] r1243 [0:609];
  reg signed [10:0] r1244 [0:609];
  reg signed [16:0] r1245 [0:9];
  reg r1246 [0:9];
  reg signed [9:0] r1247 [0:9];
  reg signed [9:0] r1248 [0:9];
  reg signed [9:0] r1249 [0:0];
  reg signed [9:0] r1250 [0:9];
  reg signed [9:0] r1251 [0:9];
  reg signed [5:0] r1252 [0:299];
  reg signed [9:0] r1253 [0:299];
  reg signed [9:0] r1254 [0:0];
  reg signed [9:0] r1255 [0:299];
  reg signed [9:0] r1256 [0:0];
  reg signed [9:0] r1257 [0:299];
  reg signed [5:0] r1258 [0:299];
  reg signed [8:0] r1259 [0:299];
  reg signed [9:0] r1260 [0:0];
  reg signed [9:0] r1261 [0:299];
  reg signed [9:0] r1262 [0:0];
  reg signed [9:0] r1263 [0:299];
  reg signed [9:0] r1264 [0:599];
  reg signed [0:0] r1265 [0:9];
  reg signed [0:0] r1266 [0:9];
  reg signed [9:0] r1267 [0:609];
  reg signed [9:0] r1268 [0:609];
  reg signed [9:0] r1269 [0:9];
  reg signed [9:0] r1270 [0:9];
  reg signed [31:0] r1271 [0:609];
  reg signed [31:0] r1272 [0:0];
  reg signed [31:0] r1273 [0:0];
  reg signed [31:0] r1274 [0:9];
  reg signed [31:0] r1275 [0:9];
  reg signed [4:0] r1276 [0:0];
  reg signed [10:0] r1277 [0:9];
  reg signed [9:0] r1278 [0:9];
  reg signed [9:0] r1279 [0:9];
  reg signed [10:0] r1280 [0:609];
  reg signed [10:0] r1281 [0:609];
  reg signed [16:0] r1282 [0:9];
  reg r1283 [0:9];
  reg signed [9:0] r1284 [0:9];
  reg signed [9:0] r1285 [0:9];
  reg signed [9:0] r1286 [0:0];
  reg signed [9:0] r1287 [0:9];
  reg signed [9:0] r1288 [0:9];
  reg signed [9:0] r1289 [0:9];
  reg signed [9:0] r1290 [0:9];
  reg signed [9:0] r1291 [0:19];
  reg signed [9:0] r1292 [0:9];
  reg signed [10:0] r1294 [0:9];
  reg signed [31:0] r1295 [0:19];
  reg signed [31:0] r1296 [0:0];
  reg signed [31:0] r1297 [0:0];
  reg signed [31:0] r1298 [0:9];
  reg signed [31:0] r1299 [0:9];
  reg signed [4:0] r1300 [0:0];
  reg signed [11:0] r1301 [0:9];
  reg signed [10:0] r1302 [0:9];
  reg signed [10:0] r1303 [0:9];
  reg signed [10:0] r1304 [0:19];
  reg signed [10:0] r1305 [0:19];
  reg signed [11:0] r1306 [0:9];
  reg r1307 [0:9];
  reg signed [10:0] r1308 [0:9];
  reg signed [10:0] r1309 [0:9];
  reg signed [10:0] r1310 [0:0];
  reg signed [10:0] r1311 [0:9];
  reg signed [10:0] r1312 [0:9];
  reg signed [10:0] r1313 [0:9];
  reg signed [10:0] r1314 [0:9];
  reg signed [10:0] r1315 [0:9];
  reg signed [10:0] r1316 [0:9];
  reg signed [10:0] r1317 [0:9];
  reg signed [31:0] rom0_c [0:79];
  reg signed [31:0] rom1_c [0:5];
  reg signed [31:0] rom2_c [0:29];
  reg signed [31:0] rom3_c [0:29];
  reg signed [31:0] rom4_c [0:29];
  reg signed [31:0] rom5_c [0:299];
  reg signed [31:0] rom6_c [0:299];
  reg signed [31:0] rom7_c [0:9];
  reg signed [31:0] rom8_lit [0:0];
  reg signed [31:0] rom9_lit [0:0];
  reg signed [31:0] rom10_lit [0:0];
  reg signed [31:0] rom11_lit [0:0];
  reg signed [31:0] rom12_lit [0:0];
  reg signed [31:0] rom13_lit [0:0];
  reg signed [31:0] rom14_lit [0:0];
  reg signed [31:0] rom15_lit [0:0];
  reg signed [31:0] rom16_lit [0:0];
  reg signed [31:0] rom17_lit [0:0];
  reg signed [31:0] rom18_lit [0:0];
  reg signed [31:0] rom19_lit [0:0];
  reg signed [31:0] rom20_lit [0:0];
  reg signed [31:0] rom21_lit [0:0];
  reg signed [31:0] rom22_lit [0:0];
  reg signed [31:0] rom23_lit [0:0];
  reg signed [31:0] rom24_lit [0:0];
  reg signed [31:0] rom25_lit [0:0];
  reg signed [31:0] rom26_lit [0:0];
  reg signed [31:0] rom27_lit [0:0];
  reg signed [31:0] rom28_lit [0:0];
  reg signed [31:0] rom29_lit [0:0];
  reg signed [31:0] rom30_lit [0:0];
  reg signed [31:0] rom31_lit [0:0];
  reg signed [31:0] rom32_lit [0:0];
  reg signed [31:0] rom33_lit [0:0];
  reg signed [31:0] rom34_lit [0:0];
  reg signed [31:0] t0;
  reg signed [31:0] t1;
  reg signed [31:0] t2;
  reg signed [31:0] t3;
  reg signed [31:0] t4;
  reg signed [31:0] t5;
  reg signed [31:0] t6;
  reg signed [31:0] t7;
  reg signed [31:0] t8;
  reg signed [31:0] t9;
  integer a0;
  integer a1;
  integer a2;
  integer a3;
  integer c0;
  integer c1;
  integer c2;
  integer c3;
  integer k0;
  integer o0x0;
  integer o0y0;
  integer k1;
  integer k2;
  integer k3;
  integer o3x0;
  integer o3y0;
  integer k4;
  integer k5;
  integer k6;
  integer o6x0;
  integer o6y0;
  integer k7;
  integer k8;
  integer k9;
  integer o9x0;
  integer o9y0;
  integer k10;
  integer k11;
  integer k12;
  integer o12x0;
  integer o12y0;
  integer k13;
  integer k14;
  integer k15;
  integer o15x0;
  integer o15y0;
  integer k16;
  integer k17;
  integer k18;
  integer o18x0;
  integer o18y0;
  integer k19;
  integer k20;
  integer k21;
  integer o21x0;
  integer o21y0;
  integer k22;
  integer k23;
  integer k24;
  integer k25;
  integer k26;
  integer k27;
  integer k28;
  integer k29;
  integer k30;
  integer k31;
  integer k32;
  integer state;
  initial $readmemh("rom/rom0_c.mem", rom0_c);
  initial $readmemh("rom/rom1_c.mem", rom1_c);
  initial $readmemh("rom/rom2_c.mem", rom2_c);
  initial $readmemh("rom/rom3_c.mem", rom3_c);
  initial $readmemh("rom/rom4_c.mem", rom4_c);
  initial $readmemh("rom/rom5_c.mem", rom5_c);
  initial $readmemh("rom/rom6_c.mem", rom6_c);
  initial $readmemh("rom/rom7_c.mem", rom7_c);
  initial $readmemh("rom/rom8_lit.mem", rom8_lit);
  initial $readmemh("rom/rom9_lit.mem", rom9_lit);
  initial $readmemh("rom/rom10_lit.mem", rom10_lit);
  initial $readmemh("rom/rom11_lit.mem", rom11_lit);
  initial $readmemh("rom/rom12_lit.mem", rom12_lit);
  initial $readmemh("rom/rom13_lit.mem", rom13_lit);
  initial $readmemh("rom/rom14_lit.mem", rom14_lit);
  initial $readmemh("rom/rom15_lit.mem", rom15_lit);
  initial $readmemh("rom/rom16_lit.mem", rom16_lit);
  initial $readmemh("rom/rom17_lit.mem", rom17_lit);
  initial $readmemh("rom/rom18_lit.mem", rom18_lit);
  initial $readmemh("rom/rom19_lit.mem", rom19_lit);
  initial $readmemh("rom/rom20_lit.mem", rom20_lit);
  initial $readmemh("rom/rom21_lit.mem", rom21_lit);
  initial $readmemh("rom/rom22_lit.mem", rom22_lit);
  initial $readmemh("rom/rom23_lit.mem", rom23_lit);
  initial $readmemh("rom/rom24_lit.mem", rom24_lit);
  initial $readmemh("rom/rom25_lit.mem", rom25_lit);
  initial $readmemh("rom/rom26_lit.mem", rom26_lit);
  initial $readmemh("rom/rom27_lit.mem", rom27_lit);
  initial $readmemh("rom/rom28_lit.mem", rom28_lit);
  initial $readmemh("rom/rom29_lit.mem", rom29_lit);
  initial $readmemh("rom/rom30_lit.mem", rom30_lit);
  initial $readmemh("rom/rom31_lit.mem", rom31_lit);
  initial $readmemh("rom/rom32_lit.mem", rom32_lit);
  initial $readmemh("rom/rom33_lit.mem", rom33_lit);
  initial $readmemh("rom/rom34_lit.mem", rom34_lit);
  always @(posedge clk) begin
    if (rst) begin
      state <= 0;
      done <= 0;
    end else begin
      case (state)
      0: begin if (start) state <= 1; end
      1: begin  // instr 0 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16000; c1 = c1 + 1) begin
            t0 = $signed(r0[a1]);
            t1 = t0 << 1;
            r10[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 16000;
        end
        state <= 2;
      end
      2: begin  // instr 1 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(rom0_c[a1]);
            t1 = t0;
            r11[a0] = t1[5:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 3;
      end
      3: begin  // instr 2 rev
        a0 = 0;
        a1 = 15;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r11[a1]);
            r12[a0] = t0[5:0];
            a0 = a0 + 1;
            a1 = a1 - 1;
          end
          a1 = a1 + 32;
        end
        state <= 4;
      end
      4: begin  // instr 3 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r12[a1]);
          r13[a0] = t0[5:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 5;
      end
      5: begin  // instr 4 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r15[a0] = t1[0:0];
        state <= 6;
      end
      6: begin  // instr 5 pad
        t0 = $signed(r15[0]);
        a0 = 0;
        for (c0 = 0; c0 < 16015; c0 = c0 + 1) begin
          r16[a0] = t0[8:0];
          a0 = a0 + 1;
        end
        state <= 7;
      end
      7: begin  // pad.scatter
        a0 = 15;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16000; c1 = c1 + 1) begin
            t1 = $signed(r10[a1]);
            r16[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 15;
        end
        state <= 8;
      end
      8: begin  // instr 6 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r17[a0] = t1[0:0];
        state <= 9;
      end
      9: begin  // instr 7 pad
        t0 = $signed(r17[0]);
        a0 = 0;
        for (c0 = 0; c0 < 16399; c0 = c0 + 1) begin
          r18[a0] = t0[8:0];
          a0 = a0 + 1;
        end
        state <= 10;
      end
      10: begin  // pad.scatter
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16015; c1 = c1 + 1) begin
            t1 = $signed(r16[a1]);
            r18[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 384;
        end
        state <= 11;
      end
      11: begin  // instr 8 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = a1;
          r19[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 12;
      end
      12: begin  // instr 9 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r19[a1]);
            r20[a0] = t0[10:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 13;
      end
      13: begin  // instr 10 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 16; c0 = c0 + 1) begin
          t0 = a1;
          r21[a0] = t0[4:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 14;
      end
      14: begin  // instr 11 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r21[a1]);
            r22[a0] = t0[4:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 16;
        end
        state <= 15;
      end
      15: begin  // instr 12 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r20[a1]);
            t1 = $signed(r22[a2]);
            t2 = t0 + t1;
            r23[a0] = t2[11:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 1;
          a2 = a2 - 16;
        end
        state <= 16;
      end
      16: begin  // instr 13 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 16; c0 = c0 + 1) begin
          t0 = a1;
          r24[a0] = t0[4:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 17;
      end
      17: begin  // instr 14 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 16; c0 = c0 + 1) begin
          t0 = $signed(r24[a1]);
          t1 = t0 << 10;
          r25[a0] = t1[14:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 18;
      end
      18: begin  // instr 15 loop
        k0 = 0;
        o0x0 = 0;
        o0y0 = 0;
        state <= 19;
      end
      19: begin  // loop0.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 16399; c0 = c0 + 1) begin
          t0 = $signed(r18[a1]);
          r26[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 20;
      end
      20: begin  // loop0.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 16384; c0 = c0 + 1) begin
          t0 = $signed(r23[a1]);
          r27[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 21;
      end
      21: begin  // loop0.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r13[a1]);
          r28[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 22;
      end
      22: begin  // loop0.head
        if (k0 == 16) state <= 121;
        else state <= 23;
      end
      23: begin  // loop0.x0
        a0 = 0;
        a1 = o0x0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r25[a1]);
          r29[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 24;
      end
      24: begin  // instr 16 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r30[a0] = t2[1:0];
        state <= 25;
      end
      25: begin  // instr 17 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        t0 = (rom10_lit[a1] != 0);
        t1 = $signed(rom9_lit[a2]);
        t2 = $signed(r30[a3]);
        t3 = (t0 != 0) ? t2 : t1;
        r32[a0] = t3[0:0];
        state <= 26;
      end
      26: begin  // instr 18 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r29[a1]);
        t1 = $signed(rom9_lit[a2]);
        t2 = (t0 < t1) ? 1 : 0;
        r33[a0] = (t2 != 0);
        state <= 27;
      end
      27: begin  // instr 19 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r29[a1]);
        t1 = $signed(rom11_lit[a2]);
        t2 = t0 + t1;
        r35[a0] = t2[15:0];
        state <= 28;
      end
      28: begin  // instr 20 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        t0 = r33[a1];
        t1 = $signed(r29[a2]);
        t2 = $signed(r35[a3]);
        t3 = (t0 != 0) ? t2 : t1;
        r36[a0] = t3[14:0];
        state <= 29;
      end
      29: begin  // instr 21 dynamic_slice
        t9 = 0;
        t0 = $signed(r32[0]);
        t1 = (t0 < 0) ? 0 : t0;
        t1 = (t1 > 0) ? 0 : t1;
        t2 = t1;
        t2 = t2 + (t1 << 1);
        t2 = t2 + (t1 << 2);
        t2 = t2 + (t1 << 3);
        t2 = t2 + (t1 << 14);
        t9 = t9 + t2;
        t0 = $signed(r36[0]);
        t1 = (t0 < 0) ? 0 : t0;
        t1 = (t1 > 15360) ? 15360 : t1;
        t2 = t1;
        t9 = t9 + t2;
        a0 = 0;
        a1 = t9;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1039; c1 = c1 + 1) begin
            t0 = $signed(r26[a1]);
            r37[a0] = t0[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 + 15360;
        end
        state <= 30;
      end
      30: begin  // instr 22 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r27[a1]);
            t1 = $signed(rom9_lit[a2]);
            t2 = (t0 < t1) ? 1 : 0;
            r38[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 31;
      end
      31: begin  // instr 23 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r27[a1]);
            t1 = $signed(rom12_lit[a2]);
            t2 = t0 + t1;
            r40[a0] = t2[12:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 32;
      end
      32: begin  // instr 24 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = r38[a1];
            t1 = $signed(r27[a2]);
            t2 = $signed(r40[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r41[a0] = t3[11:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
        end
        state <= 33;
      end
      33: begin  // instr 25 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r41[a1]);
              r42[a0] = t0[11:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
        end
        state <= 34;
      end
      34: begin  // instr 26 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1024; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 16; c2 = c2 + 1) begin
              t9 = 0;
              t0 = $signed(r42[a2]);
              t1 = (t0 < 0) ? 0 : t0;
              t1 = (t1 > 1038) ? 1038 : t1;
              t2 = t1;
              t9 = t9 + t2;
              t3 = $signed(r37[a1 + t9]);
              r43[a0] = t3[8:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a1 = a1 + 1039;
          a2 = a2 - 16384;
        end
        state <= 35;
      end
      35: begin  // instr 27 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r43[a1]);
                r44[a0] = t0[8:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
        end
        state <= 36;
      end
      36: begin  // instr 28 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r28[a1]);
                t1 = $signed(r44[a2]);
                t2 = t0 + t1;
                r45[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 16;
            end
            a2 = a2 - 16384;
          end
          a1 = a1 + 16;
        end
        state <= 37;
      end
      37: begin  // instr 29 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r48[a0] = t1[9:0];
        state <= 38;
      end
      38: begin  // instr 30 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r48[a1]);
                t1 = $signed(r45[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r49[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 16384;
          end
          a2 = a2 + 16384;
        end
        state <= 39;
      end
      39: begin  // instr 31 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r50[a0] = t1[9:0];
        state <= 40;
      end
      40: begin  // instr 32 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r50[a1]);
                t1 = $signed(r49[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r51[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 16384;
          end
          a2 = a2 + 16384;
        end
        state <= 41;
      end
      41: begin  // instr 33 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r28[a1]);
                t1 = $signed(r44[a2]);
                t2 = t0 - t1;
                r52[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 16;
            end
            a2 = a2 - 16384;
          end
          a1 = a1 + 16;
        end
        state <= 42;
      end
      42: begin  // instr 34 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r53[a0] = t1[9:0];
        state <= 43;
      end
      43: begin  // instr 35 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r53[a1]);
                t1 = $signed(r52[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r54[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 16384;
          end
          a2 = a2 + 16384;
        end
        state <= 44;
      end
      44: begin  // instr 36 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r55[a0] = t1[9:0];
        state <= 45;
      end
      45: begin  // instr 37 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r55[a1]);
                t1 = $signed(r54[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r56[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 16384;
          end
          a2 = a2 + 16384;
        end
        state <= 46;
      end
      46: begin  // instr 38 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r51[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r57[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 47;
      end
      47: begin  // instr 39 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          r58[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 48;
      end
      48: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r58[a0]);
                t1 = $signed(r57[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r58[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 49;
      end
      49: begin  // instr 40 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r58[a1]);
              t1 = $signed(rom15_lit[a2]);
              t2 = t0 - t1;
              r60[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 50;
      end
      50: begin  // instr 41 loop
        k1 = 0;
        state <= 51;
      end
      51: begin  // loop1.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 81920; c0 = c0 + 1) begin
          t0 = $signed(r51[a1]);
          r61[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 52;
      end
      52: begin  // loop1.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom15_lit[a1]);
          r62[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 53;
      end
      53: begin  // loop1.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r63[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 54;
      end
      54: begin  // loop1.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r60[a1]);
          r64[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 55;
      end
      55: begin  // loop1.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r58[a1]);
          r65[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 56;
      end
      56: begin  // loop1.head
        if (k1 == 12) state <= 79;
        else state <= 57;
      end
      57: begin  // instr 42 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r63[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r66[a0] = t2[4:0];
        state <= 58;
      end
      58: begin  // instr 43 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r64[a1]);
              t1 = $signed(r65[a2]);
              t2 = t0 + t1;
              r67[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
        end
        state <= 59;
      end
      59: begin  // instr 44 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r67[a1]);
              t1 = t0 >>> 1;
              r68[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 60;
      end
      60: begin  // instr 45 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r68[a1]);
                r69[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 61;
      end
      61: begin  // instr 46 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r61[a1]);
                t1 = $signed(r69[a2]);
                t2 = t0 - t1;
                r70[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 16384;
            a2 = a2 - 1024;
          end
          a1 = a1 + 16384;
          a2 = a2 + 1024;
        end
        state <= 62;
      end
      62: begin  // instr 47 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r70[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r71[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 63;
      end
      63: begin  // instr 48 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          r72[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 64;
      end
      64: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r72[a0]);
                t1 = $signed(r71[a1]);
                t2 = t0 + t1;
                r72[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 65;
      end
      65: begin  // instr 49 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r61[a1]);
                t1 = 0 - t0;
                r73[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 66;
      end
      66: begin  // instr 50 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r68[a1]);
                r74[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 67;
      end
      67: begin  // instr 51 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r73[a1]);
                t1 = $signed(r74[a2]);
                t2 = t0 - t1;
                r75[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 16384;
            a2 = a2 - 1024;
          end
          a1 = a1 + 16384;
          a2 = a2 + 1024;
        end
        state <= 68;
      end
      68: begin  // instr 52 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r75[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r76[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 69;
      end
      69: begin  // instr 53 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          r77[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 70;
      end
      70: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r77[a0]);
                t1 = $signed(r76[a1]);
                t2 = t0 + t1;
                r77[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 71;
      end
      71: begin  // instr 54 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r72[a1]);
              t1 = $signed(r77[a2]);
              t2 = t0 + t1;
              r78[a0] = t2[15:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
        end
        state <= 72;
      end
      72: begin  // instr 55 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r78[a1]);
              t1 = $signed(r62[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r79[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 73;
      end
      73: begin  // instr 56 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r79[a1];
              t1 = $signed(r64[a2]);
              t2 = $signed(r68[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r80[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
          a3 = a3 + 1024;
        end
        state <= 74;
      end
      74: begin  // instr 57 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r79[a1];
              t1 = $signed(r68[a2]);
              t2 = $signed(r65[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r81[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
          a3 = a3 + 1024;
        end
        state <= 75;
      end
      75: begin  // loop1.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r66[a1]);
          r63[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 76;
      end
      76: begin  // loop1.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r80[a1]);
          r64[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 77;
      end
      77: begin  // loop1.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r81[a1]);
          r65[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 78;
      end
      78: begin  // loop1.adv
        k1 = k1 + 1;
        state <= 56;
      end
      79: begin  // loop1.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r63[a1]);
          r82[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 80;
      end
      80: begin  // loop1.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r64[a1]);
          r83[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 81;
      end
      81: begin  // loop1.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r65[a1]);
          r84[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 82;
      end
      82: begin  // instr 58 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r56[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r85[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 83;
      end
      83: begin  // instr 59 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          r86[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 84;
      end
      84: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r86[a0]);
                t1 = $signed(r85[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r86[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 85;
      end
      85: begin  // instr 60 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r86[a1]);
              t1 = $signed(rom15_lit[a2]);
              t2 = t0 - t1;
              r87[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 86;
      end
      86: begin  // instr 61 loop
        k2 = 0;
        state <= 87;
      end
      87: begin  // loop2.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 81920; c0 = c0 + 1) begin
          t0 = $signed(r56[a1]);
          r88[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 88;
      end
      88: begin  // loop2.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom15_lit[a1]);
          r89[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 89;
      end
      89: begin  // loop2.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r90[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 90;
      end
      90: begin  // loop2.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r87[a1]);
          r91[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 91;
      end
      91: begin  // loop2.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r86[a1]);
          r92[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 92;
      end
      92: begin  // loop2.head
        if (k2 == 12) state <= 115;
        else state <= 93;
      end
      93: begin  // instr 62 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r90[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r93[a0] = t2[4:0];
        state <= 94;
      end
      94: begin  // instr 63 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r91[a1]);
              t1 = $signed(r92[a2]);
              t2 = t0 + t1;
              r94[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
        end
        state <= 95;
      end
      95: begin  // instr 64 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r94[a1]);
              t1 = t0 >>> 1;
              r95[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 96;
      end
      96: begin  // instr 65 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r95[a1]);
                r96[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 97;
      end
      97: begin  // instr 66 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r88[a1]);
                t1 = $signed(r96[a2]);
                t2 = t0 - t1;
                r97[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 16384;
            a2 = a2 - 1024;
          end
          a1 = a1 + 16384;
          a2 = a2 + 1024;
        end
        state <= 98;
      end
      98: begin  // instr 67 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r97[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r98[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 99;
      end
      99: begin  // instr 68 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          r99[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 100;
      end
      100: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r99[a0]);
                t1 = $signed(r98[a1]);
                t2 = t0 + t1;
                r99[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 101;
      end
      101: begin  // instr 69 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r88[a1]);
                t1 = 0 - t0;
                r100[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 102;
      end
      102: begin  // instr 70 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r95[a1]);
                r101[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 103;
      end
      103: begin  // instr 71 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r100[a1]);
                t1 = $signed(r101[a2]);
                t2 = t0 - t1;
                r102[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 16384;
            a2 = a2 - 1024;
          end
          a1 = a1 + 16384;
          a2 = a2 + 1024;
        end
        state <= 104;
      end
      104: begin  // instr 72 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r102[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r103[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 105;
      end
      105: begin  // instr 73 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          r104[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 106;
      end
      106: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r104[a0]);
                t1 = $signed(r103[a1]);
                t2 = t0 + t1;
                r104[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 107;
      end
      107: begin  // instr 74 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r99[a1]);
              t1 = $signed(r104[a2]);
              t2 = t0 + t1;
              r105[a0] = t2[15:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
        end
        state <= 108;
      end
      108: begin  // instr 75 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r105[a1]);
              t1 = $signed(r89[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r106[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 109;
      end
      109: begin  // instr 76 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r106[a1];
              t1 = $signed(r91[a2]);
              t2 = $signed(r95[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r107[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
          a3 = a3 + 1024;
        end
        state <= 110;
      end
      110: begin  // instr 77 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r106[a1];
              t1 = $signed(r95[a2]);
              t2 = $signed(r92[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r108[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
          a3 = a3 + 1024;
        end
        state <= 111;
      end
      111: begin  // loop2.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r93[a1]);
          r90[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 112;
      end
      112: begin  // loop2.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r107[a1]);
          r91[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 113;
      end
      113: begin  // loop2.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r108[a1]);
          r92[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 114;
      end
      114: begin  // loop2.adv
        k2 = k2 + 1;
        state <= 92;
      end
      115: begin  // loop2.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r90[a1]);
          r109[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 116;
      end
      116: begin  // loop2.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r91[a1]);
          r110[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 117;
      end
      117: begin  // loop2.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r92[a1]);
          r111[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 118;
      end
      118: begin  // instr 78 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r84[a1]);
              t1 = $signed(r111[a2]);
              t2 = t0 - t1;
              r112[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
        end
        state <= 119;
      end
      119: begin  // loop0.y0
        a0 = o0y0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r112[a1]);
          r113[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 120;
      end
      120: begin  // loop0.adv
        k0 = k0 + 1;
        o0x0 = o0x0 + 1;
        o0y0 = o0y0 + 5120;
        state <= 22;
      end
      121: begin  // loop0.exit
        t0 = 0;
        state <= 122;
      end
      122: begin  // instr 79 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 16; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1024; c3 = c3 + 1) begin
                t0 = $signed(r113[a1]);
                r114[a0] = t0[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a1 = a1 + 4096;
            end
            a1 = a1 - 80896;
          end
        end
        state <= 123;
      end
      123: begin  // instr 80 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 81920; c0 = c0 + 1) begin
          t0 = $signed(r114[a1]);
          r115[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 124;
      end
      124: begin  // instr 81 slice
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 16000; c2 = c2 + 1) begin
              t0 = $signed(r115[a1]);
              r116[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 + 384;
          end
        end
        state <= 125;
      end
      125: begin  // instr 82 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 16000; c2 = c2 + 1) begin
              t0 = $signed(r116[a1]);
              r117[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 64000;
        end
        state <= 126;
      end
      126: begin  // instr 83 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 16000; c2 = c2 + 1) begin
              t0 = $signed(r117[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r118[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 80000;
        end
        state <= 127;
      end
      127: begin  // instr 84 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          r119[a0] = t0[24:0];
          a0 = a0 + 1;
        end
        state <= 128;
      end
      128: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 16000; c2 = c2 + 1) begin
              t0 = $signed(r119[a0]);
              t1 = $signed(r118[a1]);
              t2 = t0 + t1;
              r119[a0] = t2[24:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 129;
      end
      129: begin  // instr 85 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r119[a1]);
            t1 = t0 << 0;
            r120[a0] = t1[24:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 5;
        end
        state <= 130;
      end
      130: begin  // instr 86 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16000; c1 = c1 + 1) begin
            t0 = $signed(r0[a1]);
            t1 = t0 << 1;
            r121[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 16000;
        end
        state <= 131;
      end
      131: begin  // instr 87 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(rom1_c[a1]);
            t1 = t0;
            r122[a0] = t1[6:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 6;
        end
        state <= 132;
      end
      132: begin  // instr 88 rev
        a0 = 0;
        a1 = 5;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r122[a1]);
            r123[a0] = t0[6:0];
            a0 = a0 + 1;
            a1 = a1 - 1;
          end
          a1 = a1 + 12;
        end
        state <= 133;
      end
      133: begin  // instr 89 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6; c0 = c0 + 1) begin
          t0 = $signed(r123[a1]);
          r124[a0] = t0[6:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 134;
      end
      134: begin  // instr 90 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r125[a0] = t1[0:0];
        state <= 135;
      end
      135: begin  // instr 91 pad
        t0 = $signed(r125[0]);
        a0 = 0;
        for (c0 = 0; c0 < 16005; c0 = c0 + 1) begin
          r126[a0] = t0[8:0];
          a0 = a0 + 1;
        end
        state <= 136;
      end
      136: begin  // pad.scatter
        a0 = 5;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16000; c1 = c1 + 1) begin
            t1 = $signed(r121[a1]);
            r126[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 5;
        end
        state <= 137;
      end
      137: begin  // instr 92 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r127[a0] = t1[0:0];
        state <= 138;
      end
      138: begin  // instr 93 pad
        t0 = $signed(r127[0]);
        a0 = 0;
        for (c0 = 0; c0 < 16389; c0 = c0 + 1) begin
          r128[a0] = t0[8:0];
          a0 = a0 + 1;
        end
        state <= 139;
      end
      139: begin  // pad.scatter
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16005; c1 = c1 + 1) begin
            t1 = $signed(r126[a1]);
            r128[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 384;
        end
        state <= 140;
      end
      140: begin  // instr 94 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = a1;
          r129[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 141;
      end
      141: begin  // instr 95 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r129[a1]);
            r130[a0] = t0[10:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 142;
      end
      142: begin  // instr 96 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6; c0 = c0 + 1) begin
          t0 = a1;
          r131[a0] = t0[3:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 143;
      end
      143: begin  // instr 97 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r131[a1]);
            r132[a0] = t0[3:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 6;
        end
        state <= 144;
      end
      144: begin  // instr 98 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r130[a1]);
            t1 = $signed(r132[a2]);
            t2 = t0 + t1;
            r133[a0] = t2[11:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 1;
          a2 = a2 - 6;
        end
        state <= 145;
      end
      145: begin  // instr 99 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 16; c0 = c0 + 1) begin
          t0 = a1;
          r134[a0] = t0[4:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 146;
      end
      146: begin  // instr 100 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 16; c0 = c0 + 1) begin
          t0 = $signed(r134[a1]);
          t1 = t0 << 10;
          r135[a0] = t1[14:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 147;
      end
      147: begin  // instr 101 loop
        k3 = 0;
        o3x0 = 0;
        o3y0 = 0;
        state <= 148;
      end
      148: begin  // loop3.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 16389; c0 = c0 + 1) begin
          t0 = $signed(r128[a1]);
          r136[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 149;
      end
      149: begin  // loop3.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6144; c0 = c0 + 1) begin
          t0 = $signed(r133[a1]);
          r137[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 150;
      end
      150: begin  // loop3.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6; c0 = c0 + 1) begin
          t0 = $signed(r124[a1]);
          r138[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 151;
      end
      151: begin  // loop3.head
        if (k3 == 16) state <= 250;
        else state <= 152;
      end
      152: begin  // loop3.x0
        a0 = 0;
        a1 = o3x0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r135[a1]);
          r139[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 153;
      end
      153: begin  // instr 102 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r140[a0] = t2[1:0];
        state <= 154;
      end
      154: begin  // instr 103 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        t0 = (rom10_lit[a1] != 0);
        t1 = $signed(rom9_lit[a2]);
        t2 = $signed(r140[a3]);
        t3 = (t0 != 0) ? t2 : t1;
        r141[a0] = t3[0:0];
        state <= 155;
      end
      155: begin  // instr 104 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r139[a1]);
        t1 = $signed(rom9_lit[a2]);
        t2 = (t0 < t1) ? 1 : 0;
        r142[a0] = (t2 != 0);
        state <= 156;
      end
      156: begin  // instr 105 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r139[a1]);
        t1 = $signed(rom16_lit[a2]);
        t2 = t0 + t1;
        r144[a0] = t2[15:0];
        state <= 157;
      end
      157: begin  // instr 106 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        t0 = r142[a1];
        t1 = $signed(r139[a2]);
        t2 = $signed(r144[a3]);
        t3 = (t0 != 0) ? t2 : t1;
        r145[a0] = t3[14:0];
        state <= 158;
      end
      158: begin  // instr 107 dynamic_slice
        t9 = 0;
        t0 = $signed(r141[0]);
        t1 = (t0 < 0) ? 0 : t0;
        t1 = (t1 > 0) ? 0 : t1;
        t2 = t1;
        t2 = t2 + (t1 << 2);
        t2 = t2 + (t1 << 14);
        t9 = t9 + t2;
        t0 = $signed(r145[0]);
        t1 = (t0 < 0) ? 0 : t0;
        t1 = (t1 > 15360) ? 15360 : t1;
        t2 = t1;
        t9 = t9 + t2;
        a0 = 0;
        a1 = t9;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1029; c1 = c1 + 1) begin
            t0 = $signed(r136[a1]);
            r146[a0] = t0[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 + 15360;
        end
        state <= 159;
      end
      159: begin  // instr 108 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r137[a1]);
            t1 = $signed(rom9_lit[a2]);
            t2 = (t0 < t1) ? 1 : 0;
            r147[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 160;
      end
      160: begin  // instr 109 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r137[a1]);
            t1 = $signed(rom17_lit[a2]);
            t2 = t0 + t1;
            r149[a0] = t2[12:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 161;
      end
      161: begin  // instr 110 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = r147[a1];
            t1 = $signed(r137[a2]);
            t2 = $signed(r149[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r150[a0] = t3[11:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
        end
        state <= 162;
      end
      162: begin  // instr 111 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r150[a1]);
              r151[a0] = t0[11:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
        end
        state <= 163;
      end
      163: begin  // instr 112 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1024; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t9 = 0;
              t0 = $signed(r151[a2]);
              t1 = (t0 < 0) ? 0 : t0;
              t1 = (t1 > 1028) ? 1028 : t1;
              t2 = t1;
              t9 = t9 + t2;
              t3 = $signed(r146[a1 + t9]);
              r152[a0] = t3[8:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a1 = a1 + 1029;
          a2 = a2 - 6144;
        end
        state <= 164;
      end
      164: begin  // instr 113 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r152[a1]);
                r153[a0] = t0[8:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 165;
      end
      165: begin  // instr 114 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r138[a1]);
                t1 = $signed(r153[a2]);
                t2 = t0 + t1;
                r154[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 6;
            end
            a2 = a2 - 6144;
          end
        end
        state <= 166;
      end
      166: begin  // instr 115 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r155[a0] = t1[9:0];
        state <= 167;
      end
      167: begin  // instr 116 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r155[a1]);
                t1 = $signed(r154[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r156[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 6144;
          end
        end
        state <= 168;
      end
      168: begin  // instr 117 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r157[a0] = t1[9:0];
        state <= 169;
      end
      169: begin  // instr 118 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r157[a1]);
                t1 = $signed(r156[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r158[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 6144;
          end
        end
        state <= 170;
      end
      170: begin  // instr 119 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r138[a1]);
                t1 = $signed(r153[a2]);
                t2 = t0 - t1;
                r159[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 6;
            end
            a2 = a2 - 6144;
          end
        end
        state <= 171;
      end
      171: begin  // instr 120 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r160[a0] = t1[9:0];
        state <= 172;
      end
      172: begin  // instr 121 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r160[a1]);
                t1 = $signed(r159[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r161[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 6144;
          end
        end
        state <= 173;
      end
      173: begin  // instr 122 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r162[a0] = t1[9:0];
        state <= 174;
      end
      174: begin  // instr 123 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r162[a1]);
                t1 = $signed(r161[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r163[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 6144;
          end
        end
        state <= 175;
      end
      175: begin  // instr 124 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r158[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r164[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 176;
      end
      176: begin  // instr 125 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          r165[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 177;
      end
      177: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r165[a0]);
                t1 = $signed(r164[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r165[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 178;
      end
      178: begin  // instr 126 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r165[a1]);
              t1 = $signed(rom15_lit[a2]);
              t2 = t0 - t1;
              r166[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 179;
      end
      179: begin  // instr 127 loop
        k4 = 0;
        state <= 180;
      end
      180: begin  // loop4.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6144; c0 = c0 + 1) begin
          t0 = $signed(r158[a1]);
          r167[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 181;
      end
      181: begin  // loop4.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom15_lit[a1]);
          r168[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 182;
      end
      182: begin  // loop4.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r169[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 183;
      end
      183: begin  // loop4.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r166[a1]);
          r170[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 184;
      end
      184: begin  // loop4.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r165[a1]);
          r171[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 185;
      end
      185: begin  // loop4.head
        if (k4 == 12) state <= 208;
        else state <= 186;
      end
      186: begin  // instr 128 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r169[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r172[a0] = t2[4:0];
        state <= 187;
      end
      187: begin  // instr 129 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r170[a1]);
              t1 = $signed(r171[a2]);
              t2 = t0 + t1;
              r173[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
        end
        state <= 188;
      end
      188: begin  // instr 130 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r173[a1]);
              t1 = t0 >>> 1;
              r174[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 189;
      end
      189: begin  // instr 131 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r174[a1]);
                r175[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 190;
      end
      190: begin  // instr 132 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r167[a1]);
                t1 = $signed(r175[a2]);
                t2 = t0 - t1;
                r176[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 6144;
            a2 = a2 - 1024;
          end
        end
        state <= 191;
      end
      191: begin  // instr 133 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r176[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r177[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 192;
      end
      192: begin  // instr 134 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          r178[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 193;
      end
      193: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r178[a0]);
                t1 = $signed(r177[a1]);
                t2 = t0 + t1;
                r178[a0] = t2[13:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 194;
      end
      194: begin  // instr 135 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r167[a1]);
                t1 = 0 - t0;
                r179[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 195;
      end
      195: begin  // instr 136 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r174[a1]);
                r180[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 196;
      end
      196: begin  // instr 137 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r179[a1]);
                t1 = $signed(r180[a2]);
                t2 = t0 - t1;
                r181[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 6144;
            a2 = a2 - 1024;
          end
        end
        state <= 197;
      end
      197: begin  // instr 138 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r181[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r182[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 198;
      end
      198: begin  // instr 139 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          r183[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 199;
      end
      199: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r183[a0]);
                t1 = $signed(r182[a1]);
                t2 = t0 + t1;
                r183[a0] = t2[13:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 200;
      end
      200: begin  // instr 140 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r178[a1]);
              t1 = $signed(r183[a2]);
              t2 = t0 + t1;
              r184[a0] = t2[14:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
        end
        state <= 201;
      end
      201: begin  // instr 141 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r184[a1]);
              t1 = $signed(r168[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r185[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 202;
      end
      202: begin  // instr 142 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r185[a1];
              t1 = $signed(r170[a2]);
              t2 = $signed(r174[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r186[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
        end
        state <= 203;
      end
      203: begin  // instr 143 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r185[a1];
              t1 = $signed(r174[a2]);
              t2 = $signed(r171[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r187[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
        end
        state <= 204;
      end
      204: begin  // loop4.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r172[a1]);
          r169[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 205;
      end
      205: begin  // loop4.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r186[a1]);
          r170[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 206;
      end
      206: begin  // loop4.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r187[a1]);
          r171[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 207;
      end
      207: begin  // loop4.adv
        k4 = k4 + 1;
        state <= 185;
      end
      208: begin  // loop4.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r169[a1]);
          r188[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 209;
      end
      209: begin  // loop4.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r170[a1]);
          r189[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 210;
      end
      210: begin  // loop4.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r171[a1]);
          r190[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 211;
      end
      211: begin  // instr 144 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r163[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r191[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 212;
      end
      212: begin  // instr 145 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          r192[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 213;
      end
      213: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r192[a0]);
                t1 = $signed(r191[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r192[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 214;
      end
      214: begin  // instr 146 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r192[a1]);
              t1 = $signed(rom15_lit[a2]);
              t2 = t0 - t1;
              r193[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 215;
      end
      215: begin  // instr 147 loop
        k5 = 0;
        state <= 216;
      end
      216: begin  // loop5.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6144; c0 = c0 + 1) begin
          t0 = $signed(r163[a1]);
          r194[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 217;
      end
      217: begin  // loop5.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom15_lit[a1]);
          r195[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 218;
      end
      218: begin  // loop5.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r196[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 219;
      end
      219: begin  // loop5.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r193[a1]);
          r197[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 220;
      end
      220: begin  // loop5.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r192[a1]);
          r198[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 221;
      end
      221: begin  // loop5.head
        if (k5 == 12) state <= 244;
        else state <= 222;
      end
      222: begin  // instr 148 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r196[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r199[a0] = t2[4:0];
        state <= 223;
      end
      223: begin  // instr 149 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r197[a1]);
              t1 = $signed(r198[a2]);
              t2 = t0 + t1;
              r200[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
        end
        state <= 224;
      end
      224: begin  // instr 150 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r200[a1]);
              t1 = t0 >>> 1;
              r201[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 225;
      end
      225: begin  // instr 151 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r201[a1]);
                r202[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 226;
      end
      226: begin  // instr 152 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r194[a1]);
                t1 = $signed(r202[a2]);
                t2 = t0 - t1;
                r203[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 6144;
            a2 = a2 - 1024;
          end
        end
        state <= 227;
      end
      227: begin  // instr 153 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r203[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r204[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 228;
      end
      228: begin  // instr 154 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          r205[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 229;
      end
      229: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r205[a0]);
                t1 = $signed(r204[a1]);
                t2 = t0 + t1;
                r205[a0] = t2[13:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 230;
      end
      230: begin  // instr 155 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r194[a1]);
                t1 = 0 - t0;
                r206[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 231;
      end
      231: begin  // instr 156 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r201[a1]);
                r207[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 232;
      end
      232: begin  // instr 157 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r206[a1]);
                t1 = $signed(r207[a2]);
                t2 = t0 - t1;
                r208[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 6144;
            a2 = a2 - 1024;
          end
        end
        state <= 233;
      end
      233: begin  // instr 158 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r208[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r209[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 234;
      end
      234: begin  // instr 159 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          r210[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 235;
      end
      235: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r210[a0]);
                t1 = $signed(r209[a1]);
                t2 = t0 + t1;
                r210[a0] = t2[13:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 236;
      end
      236: begin  // instr 160 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r205[a1]);
              t1 = $signed(r210[a2]);
              t2 = t0 + t1;
              r211[a0] = t2[14:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
        end
        state <= 237;
      end
      237: begin  // instr 161 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r211[a1]);
              t1 = $signed(r195[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r212[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 238;
      end
      238: begin  // instr 162 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r212[a1];
              t1 = $signed(r197[a2]);
              t2 = $signed(r201[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r213[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
        end
        state <= 239;
      end
      239: begin  // instr 163 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r212[a1];
              t1 = $signed(r201[a2]);
              t2 = $signed(r198[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r214[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
        end
        state <= 240;
      end
      240: begin  // loop5.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r199[a1]);
          r196[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 241;
      end
      241: begin  // loop5.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r213[a1]);
          r197[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 242;
      end
      242: begin  // loop5.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r214[a1]);
          r198[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 243;
      end
      243: begin  // loop5.adv
        k5 = k5 + 1;
        state <= 221;
      end
      244: begin  // loop5.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r196[a1]);
          r215[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 245;
      end
      245: begin  // loop5.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r197[a1]);
          r216[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 246;
      end
      246: begin  // loop5.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r198[a1]);
          r217[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 247;
      end
      247: begin  // instr 164 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r190[a1]);
              t1 = $signed(r217[a2]);
              t2 = t0 - t1;
              r218[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
        end
        state <= 248;
      end
      248: begin  // loop3.y0
        a0 = o3y0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r218[a1]);
          r219[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 249;
      end
      249: begin  // loop3.adv
        k3 = k3 + 1;
        o3x0 = o3x0 + 1;
        o3y0 = o3y0 + 1024;
        state <= 151;
      end
      250: begin  // loop3.exit
        t0 = 0;
        state <= 251;
      end
      251: begin  // instr 165 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 16; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1024; c3 = c3 + 1) begin
                t0 = $signed(r219[a1]);
                r220[a0] = t0[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 15360;
          end
        end
        state <= 252;
      end
      252: begin  // instr 166 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 16384; c0 = c0 + 1) begin
          t0 = $signed(r220[a1]);
          r221[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 253;
      end
      253: begin  // instr 167 slice
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 16000; c2 = c2 + 1) begin
              t0 = $signed(r221[a1]);
              r222[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 + 384;
          end
        end
        state <= 254;
      end
      254: begin  // instr 168 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 16000; c2 = c2 + 1) begin
              t0 = $signed(r222[a1]);
              r223[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
        end
        state <= 255;
      end
      255: begin  // instr 169 slice
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 16000; c2 = c2 + 1) begin
              t0 = $signed(r223[a1]);
              r224[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
        end
        state <= 256;
      end
      256: begin  // instr 170 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 16000; c0 = c0 + 1) begin
          t0 = $signed(r224[a1]);
          r225[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 257;
      end
      257: begin  // instr 171 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16000; c1 = c1 + 1) begin
            t0 = $signed(r225[a1]);
            t1 = t0 >>> 1;
            r226[a0] = t1[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 16000;
        end
        state <= 258;
      end
      258: begin  // instr 172 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom18_lit[a1]);
        t1 = t0;
        r229[a0] = t1[7:0];
        state <= 259;
      end
      259: begin  // instr 173 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16000; c1 = c1 + 1) begin
            t0 = $signed(r229[a1]);
            t1 = $signed(r226[a2]);
            t2 = (t0 < t1) ? t1 : t0;
            r230[a0] = t2[9:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a2 = a2 - 16000;
        end
        state <= 260;
      end
      260: begin  // instr 174 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom19_lit[a1]);
        t1 = t0;
        r231[a0] = t1[7:0];
        state <= 261;
      end
      261: begin  // instr 175 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16000; c1 = c1 + 1) begin
            t0 = $signed(r231[a1]);
            t1 = $signed(r230[a2]);
            t2 = (t1 < t0) ? t1 : t0;
            r232[a0] = t2[7:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a2 = a2 - 16000;
        end
        state <= 262;
      end
      262: begin  // instr 176 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 8000; c0 = c0 + 1) begin
          t0 = a1;
          r233[a0] = t0[13:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 263;
      end
      263: begin  // instr 177 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 8000; c0 = c0 + 1) begin
          t0 = $signed(r233[a1]);
          t1 = t0 << 1;
          r234[a0] = t1[14:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 264;
      end
      264: begin  // instr 178 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 8000; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          t1 = $signed(r234[a2]);
          t2 = t0 + t1;
          r235[a0] = t2[14:0];
          a0 = a0 + 1;
          a2 = a2 + 1;
        end
        state <= 265;
      end
      265: begin  // instr 179 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 8000; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r235[a1]);
            r236[a0] = t0[14:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 266;
      end
      266: begin  // instr 180 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 8000; c1 = c1 + 1) begin
            t9 = 0;
            t0 = $signed(r236[a2]);
            t1 = (t0 < 0) ? 0 : t0;
            t1 = (t1 > 15999) ? 15999 : t1;
            t2 = t1;
            t9 = t9 + t2;
            t3 = $signed(r232[a1 + t9]);
            r237[a0] = t3[7:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 16000;
          a2 = a2 - 8000;
        end
        state <= 267;
      end
      267: begin  // instr 181 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 8000; c1 = c1 + 1) begin
            t0 = $signed(r237[a1]);
            t1 = t0 << 1;
            r238[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 8000;
        end
        state <= 268;
      end
      268: begin  // instr 182 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(rom0_c[a1]);
            t1 = t0;
            r239[a0] = t1[5:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 269;
      end
      269: begin  // instr 183 rev
        a0 = 0;
        a1 = 15;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r239[a1]);
            r240[a0] = t0[5:0];
            a0 = a0 + 1;
            a1 = a1 - 1;
          end
          a1 = a1 + 32;
        end
        state <= 270;
      end
      270: begin  // instr 184 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r240[a1]);
          r241[a0] = t0[5:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 271;
      end
      271: begin  // instr 185 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r242[a0] = t1[0:0];
        state <= 272;
      end
      272: begin  // instr 186 pad
        t0 = $signed(r242[0]);
        a0 = 0;
        for (c0 = 0; c0 < 8015; c0 = c0 + 1) begin
          r243[a0] = t0[8:0];
          a0 = a0 + 1;
        end
        state <= 273;
      end
      273: begin  // pad.scatter
        a0 = 15;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 8000; c1 = c1 + 1) begin
            t1 = $signed(r238[a1]);
            r243[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 15;
        end
        state <= 274;
      end
      274: begin  // instr 187 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r244[a0] = t1[0:0];
        state <= 275;
      end
      275: begin  // instr 188 pad
        t0 = $signed(r244[0]);
        a0 = 0;
        for (c0 = 0; c0 < 8207; c0 = c0 + 1) begin
          r245[a0] = t0[8:0];
          a0 = a0 + 1;
        end
        state <= 276;
      end
      276: begin  // pad.scatter
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 8015; c1 = c1 + 1) begin
            t1 = $signed(r243[a1]);
            r245[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 192;
        end
        state <= 277;
      end
      277: begin  // instr 189 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = a1;
          r246[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 278;
      end
      278: begin  // instr 190 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r246[a1]);
            r247[a0] = t0[10:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 279;
      end
      279: begin  // instr 191 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 16; c0 = c0 + 1) begin
          t0 = a1;
          r248[a0] = t0[4:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 280;
      end
      280: begin  // instr 192 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r248[a1]);
            r249[a0] = t0[4:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 16;
        end
        state <= 281;
      end
      281: begin  // instr 193 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r247[a1]);
            t1 = $signed(r249[a2]);
            t2 = t0 + t1;
            r250[a0] = t2[11:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 1;
          a2 = a2 - 16;
        end
        state <= 282;
      end
      282: begin  // instr 194 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 8; c0 = c0 + 1) begin
          t0 = a1;
          r251[a0] = t0[3:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 283;
      end
      283: begin  // instr 195 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 8; c0 = c0 + 1) begin
          t0 = $signed(r251[a1]);
          t1 = t0 << 10;
          r252[a0] = t1[13:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 284;
      end
      284: begin  // instr 196 loop
        k6 = 0;
        o6x0 = 0;
        o6y0 = 0;
        state <= 285;
      end
      285: begin  // loop6.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 8207; c0 = c0 + 1) begin
          t0 = $signed(r245[a1]);
          r253[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 286;
      end
      286: begin  // loop6.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 16384; c0 = c0 + 1) begin
          t0 = $signed(r250[a1]);
          r254[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 287;
      end
      287: begin  // loop6.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r241[a1]);
          r255[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 288;
      end
      288: begin  // loop6.head
        if (k6 == 8) state <= 387;
        else state <= 289;
      end
      289: begin  // loop6.x0
        a0 = 0;
        a1 = o6x0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r252[a1]);
          r256[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 290;
      end
      290: begin  // instr 197 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r257[a0] = t2[1:0];
        state <= 291;
      end
      291: begin  // instr 198 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        t0 = (rom10_lit[a1] != 0);
        t1 = $signed(rom9_lit[a2]);
        t2 = $signed(r257[a3]);
        t3 = (t0 != 0) ? t2 : t1;
        r258[a0] = t3[0:0];
        state <= 292;
      end
      292: begin  // instr 199 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r256[a1]);
        t1 = $signed(rom9_lit[a2]);
        t2 = (t0 < t1) ? 1 : 0;
        r259[a0] = (t2 != 0);
        state <= 293;
      end
      293: begin  // instr 200 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r256[a1]);
        t1 = $signed(rom20_lit[a2]);
        t2 = t0 + t1;
        r261[a0] = t2[14:0];
        state <= 294;
      end
      294: begin  // instr 201 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        t0 = r259[a1];
        t1 = $signed(r256[a2]);
        t2 = $signed(r261[a3]);
        t3 = (t0 != 0) ? t2 : t1;
        r262[a0] = t3[13:0];
        state <= 295;
      end
      295: begin  // instr 202 dynamic_slice
        t9 = 0;
        t0 = $signed(r258[0]);
        t1 = (t0 < 0) ? 0 : t0;
        t1 = (t1 > 0) ? 0 : t1;
        t2 = t1;
        t2 = t2 + (t1 << 1);
        t2 = t2 + (t1 << 2);
        t2 = t2 + (t1 << 3);
        t2 = t2 + (t1 << 13);
        t9 = t9 + t2;
        t0 = $signed(r262[0]);
        t1 = (t0 < 0) ? 0 : t0;
        t1 = (t1 > 7168) ? 7168 : t1;
        t2 = t1;
        t9 = t9 + t2;
        a0 = 0;
        a1 = t9;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1039; c1 = c1 + 1) begin
            t0 = $signed(r253[a1]);
            r263[a0] = t0[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 + 7168;
        end
        state <= 296;
      end
      296: begin  // instr 203 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r254[a1]);
            t1 = $signed(rom9_lit[a2]);
            t2 = (t0 < t1) ? 1 : 0;
            r264[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 297;
      end
      297: begin  // instr 204 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r254[a1]);
            t1 = $signed(rom12_lit[a2]);
            t2 = t0 + t1;
            r265[a0] = t2[12:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 298;
      end
      298: begin  // instr 205 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = r264[a1];
            t1 = $signed(r254[a2]);
            t2 = $signed(r265[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r266[a0] = t3[11:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
        end
        state <= 299;
      end
      299: begin  // instr 206 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r266[a1]);
              r267[a0] = t0[11:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
        end
        state <= 300;
      end
      300: begin  // instr 207 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1024; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 16; c2 = c2 + 1) begin
              t9 = 0;
              t0 = $signed(r267[a2]);
              t1 = (t0 < 0) ? 0 : t0;
              t1 = (t1 > 1038) ? 1038 : t1;
              t2 = t1;
              t9 = t9 + t2;
              t3 = $signed(r263[a1 + t9]);
              r268[a0] = t3[8:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a1 = a1 + 1039;
          a2 = a2 - 16384;
        end
        state <= 301;
      end
      301: begin  // instr 208 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r268[a1]);
                r269[a0] = t0[8:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
        end
        state <= 302;
      end
      302: begin  // instr 209 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r255[a1]);
                t1 = $signed(r269[a2]);
                t2 = t0 + t1;
                r270[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 16;
            end
            a2 = a2 - 16384;
          end
          a1 = a1 + 16;
        end
        state <= 303;
      end
      303: begin  // instr 210 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r271[a0] = t1[9:0];
        state <= 304;
      end
      304: begin  // instr 211 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r271[a1]);
                t1 = $signed(r270[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r272[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 16384;
          end
          a2 = a2 + 16384;
        end
        state <= 305;
      end
      305: begin  // instr 212 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r273[a0] = t1[9:0];
        state <= 306;
      end
      306: begin  // instr 213 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r273[a1]);
                t1 = $signed(r272[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r274[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 16384;
          end
          a2 = a2 + 16384;
        end
        state <= 307;
      end
      307: begin  // instr 214 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r255[a1]);
                t1 = $signed(r269[a2]);
                t2 = t0 - t1;
                r275[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 16;
            end
            a2 = a2 - 16384;
          end
          a1 = a1 + 16;
        end
        state <= 308;
      end
      308: begin  // instr 215 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r276[a0] = t1[9:0];
        state <= 309;
      end
      309: begin  // instr 216 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r276[a1]);
                t1 = $signed(r275[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r277[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 16384;
          end
          a2 = a2 + 16384;
        end
        state <= 310;
      end
      310: begin  // instr 217 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r278[a0] = t1[9:0];
        state <= 311;
      end
      311: begin  // instr 218 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r278[a1]);
                t1 = $signed(r277[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r279[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 16384;
          end
          a2 = a2 + 16384;
        end
        state <= 312;
      end
      312: begin  // instr 219 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r274[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r280[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 313;
      end
      313: begin  // instr 220 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          r281[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 314;
      end
      314: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r281[a0]);
                t1 = $signed(r280[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r281[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 315;
      end
      315: begin  // instr 221 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r281[a1]);
              t1 = $signed(rom15_lit[a2]);
              t2 = t0 - t1;
              r282[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 316;
      end
      316: begin  // instr 222 loop
        k7 = 0;
        state <= 317;
      end
      317: begin  // loop7.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 81920; c0 = c0 + 1) begin
          t0 = $signed(r274[a1]);
          r283[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 318;
      end
      318: begin  // loop7.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom15_lit[a1]);
          r284[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 319;
      end
      319: begin  // loop7.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r285[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 320;
      end
      320: begin  // loop7.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r282[a1]);
          r286[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 321;
      end
      321: begin  // loop7.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r281[a1]);
          r287[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 322;
      end
      322: begin  // loop7.head
        if (k7 == 12) state <= 345;
        else state <= 323;
      end
      323: begin  // instr 223 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r285[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r288[a0] = t2[4:0];
        state <= 324;
      end
      324: begin  // instr 224 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r286[a1]);
              t1 = $signed(r287[a2]);
              t2 = t0 + t1;
              r289[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
        end
        state <= 325;
      end
      325: begin  // instr 225 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r289[a1]);
              t1 = t0 >>> 1;
              r290[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 326;
      end
      326: begin  // instr 226 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r290[a1]);
                r291[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 327;
      end
      327: begin  // instr 227 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r283[a1]);
                t1 = $signed(r291[a2]);
                t2 = t0 - t1;
                r292[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 16384;
            a2 = a2 - 1024;
          end
          a1 = a1 + 16384;
          a2 = a2 + 1024;
        end
        state <= 328;
      end
      328: begin  // instr 228 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r292[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r293[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 329;
      end
      329: begin  // instr 229 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          r294[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 330;
      end
      330: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r294[a0]);
                t1 = $signed(r293[a1]);
                t2 = t0 + t1;
                r294[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 331;
      end
      331: begin  // instr 230 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r283[a1]);
                t1 = 0 - t0;
                r295[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 332;
      end
      332: begin  // instr 231 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r290[a1]);
                r296[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 333;
      end
      333: begin  // instr 232 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r295[a1]);
                t1 = $signed(r296[a2]);
                t2 = t0 - t1;
                r297[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 16384;
            a2 = a2 - 1024;
          end
          a1 = a1 + 16384;
          a2 = a2 + 1024;
        end
        state <= 334;
      end
      334: begin  // instr 233 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r297[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r298[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 335;
      end
      335: begin  // instr 234 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          r299[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 336;
      end
      336: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r299[a0]);
                t1 = $signed(r298[a1]);
                t2 = t0 + t1;
                r299[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 337;
      end
      337: begin  // instr 235 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r294[a1]);
              t1 = $signed(r299[a2]);
              t2 = t0 + t1;
              r300[a0] = t2[15:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
        end
        state <= 338;
      end
      338: begin  // instr 236 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r300[a1]);
              t1 = $signed(r284[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r301[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 339;
      end
      339: begin  // instr 237 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r301[a1];
              t1 = $signed(r286[a2]);
              t2 = $signed(r290[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r302[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
          a3 = a3 + 1024;
        end
        state <= 340;
      end
      340: begin  // instr 238 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r301[a1];
              t1 = $signed(r290[a2]);
              t2 = $signed(r287[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r303[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
          a3 = a3 + 1024;
        end
        state <= 341;
      end
      341: begin  // loop7.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r288[a1]);
          r285[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 342;
      end
      342: begin  // loop7.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r302[a1]);
          r286[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 343;
      end
      343: begin  // loop7.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r303[a1]);
          r287[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 344;
      end
      344: begin  // loop7.adv
        k7 = k7 + 1;
        state <= 322;
      end
      345: begin  // loop7.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r285[a1]);
          r304[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 346;
      end
      346: begin  // loop7.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r286[a1]);
          r305[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 347;
      end
      347: begin  // loop7.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r287[a1]);
          r306[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 348;
      end
      348: begin  // instr 239 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r279[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r307[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 349;
      end
      349: begin  // instr 240 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          r308[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 350;
      end
      350: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r308[a0]);
                t1 = $signed(r307[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r308[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 351;
      end
      351: begin  // instr 241 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r308[a1]);
              t1 = $signed(rom15_lit[a2]);
              t2 = t0 - t1;
              r309[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 352;
      end
      352: begin  // instr 242 loop
        k8 = 0;
        state <= 353;
      end
      353: begin  // loop8.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 81920; c0 = c0 + 1) begin
          t0 = $signed(r279[a1]);
          r310[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 354;
      end
      354: begin  // loop8.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom15_lit[a1]);
          r311[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 355;
      end
      355: begin  // loop8.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r312[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 356;
      end
      356: begin  // loop8.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r309[a1]);
          r313[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 357;
      end
      357: begin  // loop8.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r308[a1]);
          r314[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 358;
      end
      358: begin  // loop8.head
        if (k8 == 12) state <= 381;
        else state <= 359;
      end
      359: begin  // instr 243 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r312[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r315[a0] = t2[4:0];
        state <= 360;
      end
      360: begin  // instr 244 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r313[a1]);
              t1 = $signed(r314[a2]);
              t2 = t0 + t1;
              r316[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
        end
        state <= 361;
      end
      361: begin  // instr 245 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r316[a1]);
              t1 = t0 >>> 1;
              r317[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 362;
      end
      362: begin  // instr 246 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r317[a1]);
                r318[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 363;
      end
      363: begin  // instr 247 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r310[a1]);
                t1 = $signed(r318[a2]);
                t2 = t0 - t1;
                r319[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 16384;
            a2 = a2 - 1024;
          end
          a1 = a1 + 16384;
          a2 = a2 + 1024;
        end
        state <= 364;
      end
      364: begin  // instr 248 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r319[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r320[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 365;
      end
      365: begin  // instr 249 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          r321[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 366;
      end
      366: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r321[a0]);
                t1 = $signed(r320[a1]);
                t2 = t0 + t1;
                r321[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 367;
      end
      367: begin  // instr 250 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r310[a1]);
                t1 = 0 - t0;
                r322[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 368;
      end
      368: begin  // instr 251 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r317[a1]);
                r323[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 369;
      end
      369: begin  // instr 252 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r322[a1]);
                t1 = $signed(r323[a2]);
                t2 = t0 - t1;
                r324[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 16384;
            a2 = a2 - 1024;
          end
          a1 = a1 + 16384;
          a2 = a2 + 1024;
        end
        state <= 370;
      end
      370: begin  // instr 253 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r324[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r325[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 371;
      end
      371: begin  // instr 254 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          r326[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 372;
      end
      372: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r326[a0]);
                t1 = $signed(r325[a1]);
                t2 = t0 + t1;
                r326[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 373;
      end
      373: begin  // instr 255 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r321[a1]);
              t1 = $signed(r326[a2]);
              t2 = t0 + t1;
              r327[a0] = t2[15:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
        end
        state <= 374;
      end
      374: begin  // instr 256 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r327[a1]);
              t1 = $signed(r311[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r328[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 375;
      end
      375: begin  // instr 257 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r328[a1];
              t1 = $signed(r313[a2]);
              t2 = $signed(r317[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r329[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
          a3 = a3 + 1024;
        end
        state <= 376;
      end
      376: begin  // instr 258 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r328[a1];
              t1 = $signed(r317[a2]);
              t2 = $signed(r314[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r330[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
          a3 = a3 + 1024;
        end
        state <= 377;
      end
      377: begin  // loop8.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r315[a1]);
          r312[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 378;
      end
      378: begin  // loop8.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r329[a1]);
          r313[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 379;
      end
      379: begin  // loop8.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r330[a1]);
          r314[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 380;
      end
      380: begin  // loop8.adv
        k8 = k8 + 1;
        state <= 358;
      end
      381: begin  // loop8.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r312[a1]);
          r331[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 382;
      end
      382: begin  // loop8.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r313[a1]);
          r332[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 383;
      end
      383: begin  // loop8.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r314[a1]);
          r333[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 384;
      end
      384: begin  // instr 259 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r306[a1]);
              t1 = $signed(r333[a2]);
              t2 = t0 - t1;
              r334[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
        end
        state <= 385;
      end
      385: begin  // loop6.y0
        a0 = o6y0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r334[a1]);
          r335[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 386;
      end
      386: begin  // loop6.adv
        k6 = k6 + 1;
        o6x0 = o6x0 + 1;
        o6y0 = o6y0 + 5120;
        state <= 288;
      end
      387: begin  // loop6.exit
        t0 = 0;
        state <= 388;
      end
      388: begin  // instr 260 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 8; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1024; c3 = c3 + 1) begin
                t0 = $signed(r335[a1]);
                r336[a0] = t0[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a1 = a1 + 4096;
            end
            a1 = a1 - 39936;
          end
        end
        state <= 389;
      end
      389: begin  // instr 261 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 40960; c0 = c0 + 1) begin
          t0 = $signed(r336[a1]);
          r337[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 390;
      end
      390: begin  // instr 262 slice
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 8000; c2 = c2 + 1) begin
              t0 = $signed(r337[a1]);
              r338[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 + 192;
          end
        end
        state <= 391;
      end
      391: begin  // instr 263 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 8000; c2 = c2 + 1) begin
              t0 = $signed(r338[a1]);
              r339[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 32000;
        end
        state <= 392;
      end
      392: begin  // instr 264 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 8000; c2 = c2 + 1) begin
              t0 = $signed(r339[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r340[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 40000;
        end
        state <= 393;
      end
      393: begin  // instr 265 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          r341[a0] = t0[23:0];
          a0 = a0 + 1;
        end
        state <= 394;
      end
      394: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 8000; c2 = c2 + 1) begin
              t0 = $signed(r341[a0]);
              t1 = $signed(r340[a1]);
              t2 = t0 + t1;
              r341[a0] = t2[23:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 395;
      end
      395: begin  // instr 266 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r341[a1]);
            t1 = t0 << 1;
            r342[a0] = t1[24:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 5;
        end
        state <= 396;
      end
      396: begin  // instr 267 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 8000; c1 = c1 + 1) begin
            t0 = $signed(r237[a1]);
            t1 = t0 << 1;
            r343[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 8000;
        end
        state <= 397;
      end
      397: begin  // instr 268 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(rom1_c[a1]);
            t1 = t0;
            r344[a0] = t1[6:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 6;
        end
        state <= 398;
      end
      398: begin  // instr 269 rev
        a0 = 0;
        a1 = 5;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r344[a1]);
            r345[a0] = t0[6:0];
            a0 = a0 + 1;
            a1 = a1 - 1;
          end
          a1 = a1 + 12;
        end
        state <= 399;
      end
      399: begin  // instr 270 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6; c0 = c0 + 1) begin
          t0 = $signed(r345[a1]);
          r346[a0] = t0[6:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 400;
      end
      400: begin  // instr 271 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r347[a0] = t1[0:0];
        state <= 401;
      end
      401: begin  // instr 272 pad
        t0 = $signed(r347[0]);
        a0 = 0;
        for (c0 = 0; c0 < 8005; c0 = c0 + 1) begin
          r348[a0] = t0[8:0];
          a0 = a0 + 1;
        end
        state <= 402;
      end
      402: begin  // pad.scatter
        a0 = 5;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 8000; c1 = c1 + 1) begin
            t1 = $signed(r343[a1]);
            r348[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 5;
        end
        state <= 403;
      end
      403: begin  // instr 273 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r349[a0] = t1[0:0];
        state <= 404;
      end
      404: begin  // instr 274 pad
        t0 = $signed(r349[0]);
        a0 = 0;
        for (c0 = 0; c0 < 8197; c0 = c0 + 1) begin
          r350[a0] = t0[8:0];
          a0 = a0 + 1;
        end
        state <= 405;
      end
      405: begin  // pad.scatter
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 8005; c1 = c1 + 1) begin
            t1 = $signed(r348[a1]);
            r350[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 192;
        end
        state <= 406;
      end
      406: begin  // instr 275 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = a1;
          r351[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 407;
      end
      407: begin  // instr 276 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r351[a1]);
            r352[a0] = t0[10:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 408;
      end
      408: begin  // instr 277 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6; c0 = c0 + 1) begin
          t0 = a1;
          r353[a0] = t0[3:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 409;
      end
      409: begin  // instr 278 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r353[a1]);
            r354[a0] = t0[3:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 6;
        end
        state <= 410;
      end
      410: begin  // instr 279 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r352[a1]);
            t1 = $signed(r354[a2]);
            t2 = t0 + t1;
            r355[a0] = t2[11:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 1;
          a2 = a2 - 6;
        end
        state <= 411;
      end
      411: begin  // instr 280 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 8; c0 = c0 + 1) begin
          t0 = a1;
          r356[a0] = t0[3:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 412;
      end
      412: begin  // instr 281 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 8; c0 = c0 + 1) begin
          t0 = $signed(r356[a1]);
          t1 = t0 << 10;
          r357[a0] = t1[13:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 413;
      end
      413: begin  // instr 282 loop
        k9 = 0;
        o9x0 = 0;
        o9y0 = 0;
        state <= 414;
      end
      414: begin  // loop9.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 8197; c0 = c0 + 1) begin
          t0 = $signed(r350[a1]);
          r358[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 415;
      end
      415: begin  // loop9.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6144; c0 = c0 + 1) begin
          t0 = $signed(r355[a1]);
          r359[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 416;
      end
      416: begin  // loop9.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6; c0 = c0 + 1) begin
          t0 = $signed(r346[a1]);
          r360[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 417;
      end
      417: begin  // loop9.head
        if (k9 == 8) state <= 516;
        else state <= 418;
      end
      418: begin  // loop9.x0
        a0 = 0;
        a1 = o9x0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r357[a1]);
          r361[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 419;
      end
      419: begin  // instr 283 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r362[a0] = t2[1:0];
        state <= 420;
      end
      420: begin  // instr 284 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        t0 = (rom10_lit[a1] != 0);
        t1 = $signed(rom9_lit[a2]);
        t2 = $signed(r362[a3]);
        t3 = (t0 != 0) ? t2 : t1;
        r363[a0] = t3[0:0];
        state <= 421;
      end
      421: begin  // instr 285 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r361[a1]);
        t1 = $signed(rom9_lit[a2]);
        t2 = (t0 < t1) ? 1 : 0;
        r364[a0] = (t2 != 0);
        state <= 422;
      end
      422: begin  // instr 286 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r361[a1]);
        t1 = $signed(rom21_lit[a2]);
        t2 = t0 + t1;
        r366[a0] = t2[14:0];
        state <= 423;
      end
      423: begin  // instr 287 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        t0 = r364[a1];
        t1 = $signed(r361[a2]);
        t2 = $signed(r366[a3]);
        t3 = (t0 != 0) ? t2 : t1;
        r367[a0] = t3[13:0];
        state <= 424;
      end
      424: begin  // instr 288 dynamic_slice
        t9 = 0;
        t0 = $signed(r363[0]);
        t1 = (t0 < 0) ? 0 : t0;
        t1 = (t1 > 0) ? 0 : t1;
        t2 = t1;
        t2 = t2 + (t1 << 2);
        t2 = t2 + (t1 << 13);
        t9 = t9 + t2;
        t0 = $signed(r367[0]);
        t1 = (t0 < 0) ? 0 : t0;
        t1 = (t1 > 7168) ? 7168 : t1;
        t2 = t1;
        t9 = t9 + t2;
        a0 = 0;
        a1 = t9;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1029; c1 = c1 + 1) begin
            t0 = $signed(r358[a1]);
            r368[a0] = t0[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 + 7168;
        end
        state <= 425;
      end
      425: begin  // instr 289 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r359[a1]);
            t1 = $signed(rom9_lit[a2]);
            t2 = (t0 < t1) ? 1 : 0;
            r369[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 426;
      end
      426: begin  // instr 290 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r359[a1]);
            t1 = $signed(rom17_lit[a2]);
            t2 = t0 + t1;
            r370[a0] = t2[12:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 427;
      end
      427: begin  // instr 291 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = r369[a1];
            t1 = $signed(r359[a2]);
            t2 = $signed(r370[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r371[a0] = t3[11:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
        end
        state <= 428;
      end
      428: begin  // instr 292 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r371[a1]);
              r372[a0] = t0[11:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
        end
        state <= 429;
      end
      429: begin  // instr 293 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1024; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t9 = 0;
              t0 = $signed(r372[a2]);
              t1 = (t0 < 0) ? 0 : t0;
              t1 = (t1 > 1028) ? 1028 : t1;
              t2 = t1;
              t9 = t9 + t2;
              t3 = $signed(r368[a1 + t9]);
              r373[a0] = t3[8:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a1 = a1 + 1029;
          a2 = a2 - 6144;
        end
        state <= 430;
      end
      430: begin  // instr 294 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r373[a1]);
                r374[a0] = t0[8:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 431;
      end
      431: begin  // instr 295 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r360[a1]);
                t1 = $signed(r374[a2]);
                t2 = t0 + t1;
                r375[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 6;
            end
            a2 = a2 - 6144;
          end
        end
        state <= 432;
      end
      432: begin  // instr 296 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r376[a0] = t1[9:0];
        state <= 433;
      end
      433: begin  // instr 297 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r376[a1]);
                t1 = $signed(r375[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r377[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 6144;
          end
        end
        state <= 434;
      end
      434: begin  // instr 298 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r378[a0] = t1[9:0];
        state <= 435;
      end
      435: begin  // instr 299 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r378[a1]);
                t1 = $signed(r377[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r379[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 6144;
          end
        end
        state <= 436;
      end
      436: begin  // instr 300 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r360[a1]);
                t1 = $signed(r374[a2]);
                t2 = t0 - t1;
                r380[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 6;
            end
            a2 = a2 - 6144;
          end
        end
        state <= 437;
      end
      437: begin  // instr 301 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r381[a0] = t1[9:0];
        state <= 438;
      end
      438: begin  // instr 302 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r381[a1]);
                t1 = $signed(r380[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r382[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 6144;
          end
        end
        state <= 439;
      end
      439: begin  // instr 303 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r383[a0] = t1[9:0];
        state <= 440;
      end
      440: begin  // instr 304 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r383[a1]);
                t1 = $signed(r382[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r384[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 6144;
          end
        end
        state <= 441;
      end
      441: begin  // instr 305 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r379[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r385[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 442;
      end
      442: begin  // instr 306 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          r386[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 443;
      end
      443: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r386[a0]);
                t1 = $signed(r385[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r386[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 444;
      end
      444: begin  // instr 307 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r386[a1]);
              t1 = $signed(rom15_lit[a2]);
              t2 = t0 - t1;
              r387[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 445;
      end
      445: begin  // instr 308 loop
        k10 = 0;
        state <= 446;
      end
      446: begin  // loop10.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6144; c0 = c0 + 1) begin
          t0 = $signed(r379[a1]);
          r388[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 447;
      end
      447: begin  // loop10.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom15_lit[a1]);
          r389[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 448;
      end
      448: begin  // loop10.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r390[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 449;
      end
      449: begin  // loop10.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r387[a1]);
          r391[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 450;
      end
      450: begin  // loop10.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r386[a1]);
          r392[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 451;
      end
      451: begin  // loop10.head
        if (k10 == 12) state <= 474;
        else state <= 452;
      end
      452: begin  // instr 309 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r390[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r393[a0] = t2[4:0];
        state <= 453;
      end
      453: begin  // instr 310 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r391[a1]);
              t1 = $signed(r392[a2]);
              t2 = t0 + t1;
              r394[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
        end
        state <= 454;
      end
      454: begin  // instr 311 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r394[a1]);
              t1 = t0 >>> 1;
              r395[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 455;
      end
      455: begin  // instr 312 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r395[a1]);
                r396[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 456;
      end
      456: begin  // instr 313 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r388[a1]);
                t1 = $signed(r396[a2]);
                t2 = t0 - t1;
                r397[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 6144;
            a2 = a2 - 1024;
          end
        end
        state <= 457;
      end
      457: begin  // instr 314 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r397[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r398[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 458;
      end
      458: begin  // instr 315 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          r399[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 459;
      end
      459: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r399[a0]);
                t1 = $signed(r398[a1]);
                t2 = t0 + t1;
                r399[a0] = t2[13:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 460;
      end
      460: begin  // instr 316 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r388[a1]);
                t1 = 0 - t0;
                r400[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 461;
      end
      461: begin  // instr 317 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r395[a1]);
                r401[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 462;
      end
      462: begin  // instr 318 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r400[a1]);
                t1 = $signed(r401[a2]);
                t2 = t0 - t1;
                r402[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 6144;
            a2 = a2 - 1024;
          end
        end
        state <= 463;
      end
      463: begin  // instr 319 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r402[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r403[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 464;
      end
      464: begin  // instr 320 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          r404[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 465;
      end
      465: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r404[a0]);
                t1 = $signed(r403[a1]);
                t2 = t0 + t1;
                r404[a0] = t2[13:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 466;
      end
      466: begin  // instr 321 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r399[a1]);
              t1 = $signed(r404[a2]);
              t2 = t0 + t1;
              r405[a0] = t2[14:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
        end
        state <= 467;
      end
      467: begin  // instr 322 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r405[a1]);
              t1 = $signed(r389[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r406[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 468;
      end
      468: begin  // instr 323 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r406[a1];
              t1 = $signed(r391[a2]);
              t2 = $signed(r395[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r407[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
        end
        state <= 469;
      end
      469: begin  // instr 324 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r406[a1];
              t1 = $signed(r395[a2]);
              t2 = $signed(r392[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r408[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
        end
        state <= 470;
      end
      470: begin  // loop10.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r393[a1]);
          r390[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 471;
      end
      471: begin  // loop10.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r407[a1]);
          r391[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 472;
      end
      472: begin  // loop10.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r408[a1]);
          r392[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 473;
      end
      473: begin  // loop10.adv
        k10 = k10 + 1;
        state <= 451;
      end
      474: begin  // loop10.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r390[a1]);
          r409[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 475;
      end
      475: begin  // loop10.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r391[a1]);
          r410[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 476;
      end
      476: begin  // loop10.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r392[a1]);
          r411[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 477;
      end
      477: begin  // instr 325 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r384[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r412[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 478;
      end
      478: begin  // instr 326 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          r413[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 479;
      end
      479: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r413[a0]);
                t1 = $signed(r412[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r413[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 480;
      end
      480: begin  // instr 327 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r413[a1]);
              t1 = $signed(rom15_lit[a2]);
              t2 = t0 - t1;
              r414[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 481;
      end
      481: begin  // instr 328 loop
        k11 = 0;
        state <= 482;
      end
      482: begin  // loop11.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6144; c0 = c0 + 1) begin
          t0 = $signed(r384[a1]);
          r415[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 483;
      end
      483: begin  // loop11.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom15_lit[a1]);
          r416[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 484;
      end
      484: begin  // loop11.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r417[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 485;
      end
      485: begin  // loop11.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r414[a1]);
          r418[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 486;
      end
      486: begin  // loop11.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r413[a1]);
          r419[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 487;
      end
      487: begin  // loop11.head
        if (k11 == 12) state <= 510;
        else state <= 488;
      end
      488: begin  // instr 329 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r417[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r420[a0] = t2[4:0];
        state <= 489;
      end
      489: begin  // instr 330 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r418[a1]);
              t1 = $signed(r419[a2]);
              t2 = t0 + t1;
              r421[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
        end
        state <= 490;
      end
      490: begin  // instr 331 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r421[a1]);
              t1 = t0 >>> 1;
              r422[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 491;
      end
      491: begin  // instr 332 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r422[a1]);
                r423[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 492;
      end
      492: begin  // instr 333 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r415[a1]);
                t1 = $signed(r423[a2]);
                t2 = t0 - t1;
                r424[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 6144;
            a2 = a2 - 1024;
          end
        end
        state <= 493;
      end
      493: begin  // instr 334 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r424[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r425[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 494;
      end
      494: begin  // instr 335 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          r426[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 495;
      end
      495: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r426[a0]);
                t1 = $signed(r425[a1]);
                t2 = t0 + t1;
                r426[a0] = t2[13:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 496;
      end
      496: begin  // instr 336 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r415[a1]);
                t1 = 0 - t0;
                r427[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 497;
      end
      497: begin  // instr 337 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r422[a1]);
                r428[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 498;
      end
      498: begin  // instr 338 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r427[a1]);
                t1 = $signed(r428[a2]);
                t2 = t0 - t1;
                r429[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 6144;
            a2 = a2 - 1024;
          end
        end
        state <= 499;
      end
      499: begin  // instr 339 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r429[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r430[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 500;
      end
      500: begin  // instr 340 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          r431[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 501;
      end
      501: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r431[a0]);
                t1 = $signed(r430[a1]);
                t2 = t0 + t1;
                r431[a0] = t2[13:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 502;
      end
      502: begin  // instr 341 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r426[a1]);
              t1 = $signed(r431[a2]);
              t2 = t0 + t1;
              r432[a0] = t2[14:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
        end
        state <= 503;
      end
      503: begin  // instr 342 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r432[a1]);
              t1 = $signed(r416[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r433[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 504;
      end
      504: begin  // instr 343 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r433[a1];
              t1 = $signed(r418[a2]);
              t2 = $signed(r422[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r434[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
        end
        state <= 505;
      end
      505: begin  // instr 344 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r433[a1];
              t1 = $signed(r422[a2]);
              t2 = $signed(r419[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r435[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
        end
        state <= 506;
      end
      506: begin  // loop11.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r420[a1]);
          r417[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 507;
      end
      507: begin  // loop11.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r434[a1]);
          r418[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 508;
      end
      508: begin  // loop11.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r435[a1]);
          r419[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 509;
      end
      509: begin  // loop11.adv
        k11 = k11 + 1;
        state <= 487;
      end
      510: begin  // loop11.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r417[a1]);
          r436[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 511;
      end
      511: begin  // loop11.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r418[a1]);
          r437[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 512;
      end
      512: begin  // loop11.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r419[a1]);
          r438[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 513;
      end
      513: begin  // instr 345 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r411[a1]);
              t1 = $signed(r438[a2]);
              t2 = t0 - t1;
              r439[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
        end
        state <= 514;
      end
      514: begin  // loop9.y0
        a0 = o9y0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r439[a1]);
          r440[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 515;
      end
      515: begin  // loop9.adv
        k9 = k9 + 1;
        o9x0 = o9x0 + 1;
        o9y0 = o9y0 + 1024;
        state <= 417;
      end
      516: begin  // loop9.exit
        t0 = 0;
        state <= 517;
      end
      517: begin  // instr 346 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 8; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1024; c3 = c3 + 1) begin
                t0 = $signed(r440[a1]);
                r441[a0] = t0[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 7168;
          end
        end
        state <= 518;
      end
      518: begin  // instr 347 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 8192; c0 = c0 + 1) begin
          t0 = $signed(r441[a1]);
          r442[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 519;
      end
      519: begin  // instr 348 slice
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 8000; c2 = c2 + 1) begin
              t0 = $signed(r442[a1]);
              r443[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 + 192;
          end
        end
        state <= 520;
      end
      520: begin  // instr 349 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 8000; c2 = c2 + 1) begin
              t0 = $signed(r443[a1]);
              r444[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
        end
        state <= 521;
      end
      521: begin  // instr 350 slice
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 8000; c2 = c2 + 1) begin
              t0 = $signed(r444[a1]);
              r445[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
        end
        state <= 522;
      end
      522: begin  // instr 351 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 8000; c0 = c0 + 1) begin
          t0 = $signed(r445[a1]);
          r446[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 523;
      end
      523: begin  // instr 352 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 8000; c1 = c1 + 1) begin
            t0 = $signed(r446[a1]);
            t1 = t0 >>> 1;
            r447[a0] = t1[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 8000;
        end
        state <= 524;
      end
      524: begin  // instr 353 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom18_lit[a1]);
        t1 = t0;
        r448[a0] = t1[7:0];
        state <= 525;
      end
      525: begin  // instr 354 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 8000; c1 = c1 + 1) begin
            t0 = $signed(r448[a1]);
            t1 = $signed(r447[a2]);
            t2 = (t0 < t1) ? t1 : t0;
            r449[a0] = t2[9:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a2 = a2 - 8000;
        end
        state <= 526;
      end
      526: begin  // instr 355 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom19_lit[a1]);
        t1 = t0;
        r450[a0] = t1[7:0];
        state <= 527;
      end
      527: begin  // instr 356 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 8000; c1 = c1 + 1) begin
            t0 = $signed(r450[a1]);
            t1 = $signed(r449[a2]);
            t2 = (t1 < t0) ? t1 : t0;
            r451[a0] = t2[7:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a2 = a2 - 8000;
        end
        state <= 528;
      end
      528: begin  // instr 357 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 4000; c0 = c0 + 1) begin
          t0 = a1;
          r452[a0] = t0[12:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 529;
      end
      529: begin  // instr 358 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 4000; c0 = c0 + 1) begin
          t0 = $signed(r452[a1]);
          t1 = t0 << 1;
          r453[a0] = t1[13:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 530;
      end
      530: begin  // instr 359 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 4000; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          t1 = $signed(r453[a2]);
          t2 = t0 + t1;
          r454[a0] = t2[13:0];
          a0 = a0 + 1;
          a2 = a2 + 1;
        end
        state <= 531;
      end
      531: begin  // instr 360 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 4000; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r454[a1]);
            r455[a0] = t0[13:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 532;
      end
      532: begin  // instr 361 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 4000; c1 = c1 + 1) begin
            t9 = 0;
            t0 = $signed(r455[a2]);
            t1 = (t0 < 0) ? 0 : t0;
            t1 = (t1 > 7999) ? 7999 : t1;
            t2 = t1;
            t9 = t9 + t2;
            t3 = $signed(r451[a1 + t9]);
            r456[a0] = t3[7:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 8000;
          a2 = a2 - 4000;
        end
        state <= 533;
      end
      533: begin  // instr 362 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 4000; c1 = c1 + 1) begin
            t0 = $signed(r456[a1]);
            t1 = t0 << 1;
            r457[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 4000;
        end
        state <= 534;
      end
      534: begin  // instr 363 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(rom0_c[a1]);
            t1 = t0;
            r458[a0] = t1[5:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 535;
      end
      535: begin  // instr 364 rev
        a0 = 0;
        a1 = 15;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r458[a1]);
            r459[a0] = t0[5:0];
            a0 = a0 + 1;
            a1 = a1 - 1;
          end
          a1 = a1 + 32;
        end
        state <= 536;
      end
      536: begin  // instr 365 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r459[a1]);
          r460[a0] = t0[5:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 537;
      end
      537: begin  // instr 366 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r461[a0] = t1[0:0];
        state <= 538;
      end
      538: begin  // instr 367 pad
        t0 = $signed(r461[0]);
        a0 = 0;
        for (c0 = 0; c0 < 4015; c0 = c0 + 1) begin
          r462[a0] = t0[8:0];
          a0 = a0 + 1;
        end
        state <= 539;
      end
      539: begin  // pad.scatter
        a0 = 15;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 4000; c1 = c1 + 1) begin
            t1 = $signed(r457[a1]);
            r462[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 15;
        end
        state <= 540;
      end
      540: begin  // instr 368 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r463[a0] = t1[0:0];
        state <= 541;
      end
      541: begin  // instr 369 pad
        t0 = $signed(r463[0]);
        a0 = 0;
        for (c0 = 0; c0 < 4111; c0 = c0 + 1) begin
          r464[a0] = t0[8:0];
          a0 = a0 + 1;
        end
        state <= 542;
      end
      542: begin  // pad.scatter
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 4015; c1 = c1 + 1) begin
            t1 = $signed(r462[a1]);
            r464[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 96;
        end
        state <= 543;
      end
      543: begin  // instr 370 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = a1;
          r465[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 544;
      end
      544: begin  // instr 371 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r465[a1]);
            r466[a0] = t0[10:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 545;
      end
      545: begin  // instr 372 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 16; c0 = c0 + 1) begin
          t0 = a1;
          r467[a0] = t0[4:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 546;
      end
      546: begin  // instr 373 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r467[a1]);
            r468[a0] = t0[4:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 16;
        end
        state <= 547;
      end
      547: begin  // instr 374 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r466[a1]);
            t1 = $signed(r468[a2]);
            t2 = t0 + t1;
            r469[a0] = t2[11:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 1;
          a2 = a2 - 16;
        end
        state <= 548;
      end
      548: begin  // instr 375 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 4; c0 = c0 + 1) begin
          t0 = a1;
          r470[a0] = t0[2:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 549;
      end
      549: begin  // instr 376 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 4; c0 = c0 + 1) begin
          t0 = $signed(r470[a1]);
          t1 = t0 << 10;
          r471[a0] = t1[12:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 550;
      end
      550: begin  // instr 377 loop
        k12 = 0;
        o12x0 = 0;
        o12y0 = 0;
        state <= 551;
      end
      551: begin  // loop12.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 4111; c0 = c0 + 1) begin
          t0 = $signed(r464[a1]);
          r472[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 552;
      end
      552: begin  // loop12.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 16384; c0 = c0 + 1) begin
          t0 = $signed(r469[a1]);
          r473[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 553;
      end
      553: begin  // loop12.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r460[a1]);
          r474[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 554;
      end
      554: begin  // loop12.head
        if (k12 == 4) state <= 653;
        else state <= 555;
      end
      555: begin  // loop12.x0
        a0 = 0;
        a1 = o12x0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r471[a1]);
          r475[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 556;
      end
      556: begin  // instr 378 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r476[a0] = t2[1:0];
        state <= 557;
      end
      557: begin  // instr 379 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        t0 = (rom10_lit[a1] != 0);
        t1 = $signed(rom9_lit[a2]);
        t2 = $signed(r476[a3]);
        t3 = (t0 != 0) ? t2 : t1;
        r477[a0] = t3[0:0];
        state <= 558;
      end
      558: begin  // instr 380 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r475[a1]);
        t1 = $signed(rom9_lit[a2]);
        t2 = (t0 < t1) ? 1 : 0;
        r478[a0] = (t2 != 0);
        state <= 559;
      end
      559: begin  // instr 381 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r475[a1]);
        t1 = $signed(rom22_lit[a2]);
        t2 = t0 + t1;
        r480[a0] = t2[13:0];
        state <= 560;
      end
      560: begin  // instr 382 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        t0 = r478[a1];
        t1 = $signed(r475[a2]);
        t2 = $signed(r480[a3]);
        t3 = (t0 != 0) ? t2 : t1;
        r481[a0] = t3[12:0];
        state <= 561;
      end
      561: begin  // instr 383 dynamic_slice
        t9 = 0;
        t0 = $signed(r477[0]);
        t1 = (t0 < 0) ? 0 : t0;
        t1 = (t1 > 0) ? 0 : t1;
        t2 = t1;
        t2 = t2 + (t1 << 1);
        t2 = t2 + (t1 << 2);
        t2 = t2 + (t1 << 3);
        t2 = t2 + (t1 << 12);
        t9 = t9 + t2;
        t0 = $signed(r481[0]);
        t1 = (t0 < 0) ? 0 : t0;
        t1 = (t1 > 3072) ? 3072 : t1;
        t2 = t1;
        t9 = t9 + t2;
        a0 = 0;
        a1 = t9;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1039; c1 = c1 + 1) begin
            t0 = $signed(r472[a1]);
            r482[a0] = t0[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 + 3072;
        end
        state <= 562;
      end
      562: begin  // instr 384 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r473[a1]);
            t1 = $signed(rom9_lit[a2]);
            t2 = (t0 < t1) ? 1 : 0;
            r483[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 563;
      end
      563: begin  // instr 385 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r473[a1]);
            t1 = $signed(rom12_lit[a2]);
            t2 = t0 + t1;
            r484[a0] = t2[12:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 564;
      end
      564: begin  // instr 386 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = r483[a1];
            t1 = $signed(r473[a2]);
            t2 = $signed(r484[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r485[a0] = t3[11:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
        end
        state <= 565;
      end
      565: begin  // instr 387 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r485[a1]);
              r486[a0] = t0[11:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
        end
        state <= 566;
      end
      566: begin  // instr 388 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1024; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 16; c2 = c2 + 1) begin
              t9 = 0;
              t0 = $signed(r486[a2]);
              t1 = (t0 < 0) ? 0 : t0;
              t1 = (t1 > 1038) ? 1038 : t1;
              t2 = t1;
              t9 = t9 + t2;
              t3 = $signed(r482[a1 + t9]);
              r487[a0] = t3[8:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a1 = a1 + 1039;
          a2 = a2 - 16384;
        end
        state <= 567;
      end
      567: begin  // instr 389 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r487[a1]);
                r488[a0] = t0[8:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
        end
        state <= 568;
      end
      568: begin  // instr 390 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r474[a1]);
                t1 = $signed(r488[a2]);
                t2 = t0 + t1;
                r489[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 16;
            end
            a2 = a2 - 16384;
          end
          a1 = a1 + 16;
        end
        state <= 569;
      end
      569: begin  // instr 391 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r490[a0] = t1[9:0];
        state <= 570;
      end
      570: begin  // instr 392 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r490[a1]);
                t1 = $signed(r489[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r491[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 16384;
          end
          a2 = a2 + 16384;
        end
        state <= 571;
      end
      571: begin  // instr 393 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r492[a0] = t1[9:0];
        state <= 572;
      end
      572: begin  // instr 394 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r492[a1]);
                t1 = $signed(r491[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r493[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 16384;
          end
          a2 = a2 + 16384;
        end
        state <= 573;
      end
      573: begin  // instr 395 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r474[a1]);
                t1 = $signed(r488[a2]);
                t2 = t0 - t1;
                r494[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 16;
            end
            a2 = a2 - 16384;
          end
          a1 = a1 + 16;
        end
        state <= 574;
      end
      574: begin  // instr 396 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r495[a0] = t1[9:0];
        state <= 575;
      end
      575: begin  // instr 397 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r495[a1]);
                t1 = $signed(r494[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r496[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 16384;
          end
          a2 = a2 + 16384;
        end
        state <= 576;
      end
      576: begin  // instr 398 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r497[a0] = t1[9:0];
        state <= 577;
      end
      577: begin  // instr 399 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r497[a1]);
                t1 = $signed(r496[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r498[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 16384;
          end
          a2 = a2 + 16384;
        end
        state <= 578;
      end
      578: begin  // instr 400 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r493[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r499[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 579;
      end
      579: begin  // instr 401 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          r500[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 580;
      end
      580: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r500[a0]);
                t1 = $signed(r499[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r500[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 581;
      end
      581: begin  // instr 402 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r500[a1]);
              t1 = $signed(rom15_lit[a2]);
              t2 = t0 - t1;
              r501[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 582;
      end
      582: begin  // instr 403 loop
        k13 = 0;
        state <= 583;
      end
      583: begin  // loop13.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 81920; c0 = c0 + 1) begin
          t0 = $signed(r493[a1]);
          r502[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 584;
      end
      584: begin  // loop13.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom15_lit[a1]);
          r503[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 585;
      end
      585: begin  // loop13.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r504[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 586;
      end
      586: begin  // loop13.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r501[a1]);
          r505[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 587;
      end
      587: begin  // loop13.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r500[a1]);
          r506[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 588;
      end
      588: begin  // loop13.head
        if (k13 == 12) state <= 611;
        else state <= 589;
      end
      589: begin  // instr 404 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r504[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r507[a0] = t2[4:0];
        state <= 590;
      end
      590: begin  // instr 405 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r505[a1]);
              t1 = $signed(r506[a2]);
              t2 = t0 + t1;
              r508[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
        end
        state <= 591;
      end
      591: begin  // instr 406 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r508[a1]);
              t1 = t0 >>> 1;
              r509[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 592;
      end
      592: begin  // instr 407 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r509[a1]);
                r510[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 593;
      end
      593: begin  // instr 408 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r502[a1]);
                t1 = $signed(r510[a2]);
                t2 = t0 - t1;
                r511[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 16384;
            a2 = a2 - 1024;
          end
          a1 = a1 + 16384;
          a2 = a2 + 1024;
        end
        state <= 594;
      end
      594: begin  // instr 409 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r511[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r512[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 595;
      end
      595: begin  // instr 410 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          r513[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 596;
      end
      596: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r513[a0]);
                t1 = $signed(r512[a1]);
                t2 = t0 + t1;
                r513[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 597;
      end
      597: begin  // instr 411 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r502[a1]);
                t1 = 0 - t0;
                r514[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 598;
      end
      598: begin  // instr 412 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r509[a1]);
                r515[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 599;
      end
      599: begin  // instr 413 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r514[a1]);
                t1 = $signed(r515[a2]);
                t2 = t0 - t1;
                r516[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 16384;
            a2 = a2 - 1024;
          end
          a1 = a1 + 16384;
          a2 = a2 + 1024;
        end
        state <= 600;
      end
      600: begin  // instr 414 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r516[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r517[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 601;
      end
      601: begin  // instr 415 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          r518[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 602;
      end
      602: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r518[a0]);
                t1 = $signed(r517[a1]);
                t2 = t0 + t1;
                r518[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 603;
      end
      603: begin  // instr 416 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r513[a1]);
              t1 = $signed(r518[a2]);
              t2 = t0 + t1;
              r519[a0] = t2[15:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
        end
        state <= 604;
      end
      604: begin  // instr 417 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r519[a1]);
              t1 = $signed(r503[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r520[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 605;
      end
      605: begin  // instr 418 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r520[a1];
              t1 = $signed(r505[a2]);
              t2 = $signed(r509[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r521[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
          a3 = a3 + 1024;
        end
        state <= 606;
      end
      606: begin  // instr 419 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r520[a1];
              t1 = $signed(r509[a2]);
              t2 = $signed(r506[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r522[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
          a3 = a3 + 1024;
        end
        state <= 607;
      end
      607: begin  // loop13.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r507[a1]);
          r504[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 608;
      end
      608: begin  // loop13.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r521[a1]);
          r505[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 609;
      end
      609: begin  // loop13.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r522[a1]);
          r506[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 610;
      end
      610: begin  // loop13.adv
        k13 = k13 + 1;
        state <= 588;
      end
      611: begin  // loop13.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r504[a1]);
          r523[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 612;
      end
      612: begin  // loop13.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r505[a1]);
          r524[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 613;
      end
      613: begin  // loop13.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r506[a1]);
          r525[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 614;
      end
      614: begin  // instr 420 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r498[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r526[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 615;
      end
      615: begin  // instr 421 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          r527[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 616;
      end
      616: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r527[a0]);
                t1 = $signed(r526[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r527[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 617;
      end
      617: begin  // instr 422 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r527[a1]);
              t1 = $signed(rom15_lit[a2]);
              t2 = t0 - t1;
              r528[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 618;
      end
      618: begin  // instr 423 loop
        k14 = 0;
        state <= 619;
      end
      619: begin  // loop14.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 81920; c0 = c0 + 1) begin
          t0 = $signed(r498[a1]);
          r529[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 620;
      end
      620: begin  // loop14.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom15_lit[a1]);
          r530[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 621;
      end
      621: begin  // loop14.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r531[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 622;
      end
      622: begin  // loop14.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r528[a1]);
          r532[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 623;
      end
      623: begin  // loop14.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r527[a1]);
          r533[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 624;
      end
      624: begin  // loop14.head
        if (k14 == 12) state <= 647;
        else state <= 625;
      end
      625: begin  // instr 424 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r531[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r534[a0] = t2[4:0];
        state <= 626;
      end
      626: begin  // instr 425 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r532[a1]);
              t1 = $signed(r533[a2]);
              t2 = t0 + t1;
              r535[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
        end
        state <= 627;
      end
      627: begin  // instr 426 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r535[a1]);
              t1 = t0 >>> 1;
              r536[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 628;
      end
      628: begin  // instr 427 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r536[a1]);
                r537[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 629;
      end
      629: begin  // instr 428 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r529[a1]);
                t1 = $signed(r537[a2]);
                t2 = t0 - t1;
                r538[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 16384;
            a2 = a2 - 1024;
          end
          a1 = a1 + 16384;
          a2 = a2 + 1024;
        end
        state <= 630;
      end
      630: begin  // instr 429 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r538[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r539[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 631;
      end
      631: begin  // instr 430 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          r540[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 632;
      end
      632: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r540[a0]);
                t1 = $signed(r539[a1]);
                t2 = t0 + t1;
                r540[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 633;
      end
      633: begin  // instr 431 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r529[a1]);
                t1 = 0 - t0;
                r541[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 634;
      end
      634: begin  // instr 432 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r536[a1]);
                r542[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 635;
      end
      635: begin  // instr 433 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r541[a1]);
                t1 = $signed(r542[a2]);
                t2 = t0 - t1;
                r543[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 16384;
            a2 = a2 - 1024;
          end
          a1 = a1 + 16384;
          a2 = a2 + 1024;
        end
        state <= 636;
      end
      636: begin  // instr 434 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r543[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r544[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 637;
      end
      637: begin  // instr 435 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          r545[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 638;
      end
      638: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r545[a0]);
                t1 = $signed(r544[a1]);
                t2 = t0 + t1;
                r545[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 639;
      end
      639: begin  // instr 436 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r540[a1]);
              t1 = $signed(r545[a2]);
              t2 = t0 + t1;
              r546[a0] = t2[15:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
        end
        state <= 640;
      end
      640: begin  // instr 437 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r546[a1]);
              t1 = $signed(r530[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r547[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 641;
      end
      641: begin  // instr 438 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r547[a1];
              t1 = $signed(r532[a2]);
              t2 = $signed(r536[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r548[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
          a3 = a3 + 1024;
        end
        state <= 642;
      end
      642: begin  // instr 439 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r547[a1];
              t1 = $signed(r536[a2]);
              t2 = $signed(r533[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r549[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
          a3 = a3 + 1024;
        end
        state <= 643;
      end
      643: begin  // loop14.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r534[a1]);
          r531[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 644;
      end
      644: begin  // loop14.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r548[a1]);
          r532[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 645;
      end
      645: begin  // loop14.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r549[a1]);
          r533[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 646;
      end
      646: begin  // loop14.adv
        k14 = k14 + 1;
        state <= 624;
      end
      647: begin  // loop14.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r531[a1]);
          r550[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 648;
      end
      648: begin  // loop14.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r532[a1]);
          r551[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 649;
      end
      649: begin  // loop14.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r533[a1]);
          r552[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 650;
      end
      650: begin  // instr 440 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r525[a1]);
              t1 = $signed(r552[a2]);
              t2 = t0 - t1;
              r553[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
        end
        state <= 651;
      end
      651: begin  // loop12.y0
        a0 = o12y0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r553[a1]);
          r554[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 652;
      end
      652: begin  // loop12.adv
        k12 = k12 + 1;
        o12x0 = o12x0 + 1;
        o12y0 = o12y0 + 5120;
        state <= 554;
      end
      653: begin  // loop12.exit
        t0 = 0;
        state <= 654;
      end
      654: begin  // instr 441 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 4; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1024; c3 = c3 + 1) begin
                t0 = $signed(r554[a1]);
                r555[a0] = t0[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a1 = a1 + 4096;
            end
            a1 = a1 - 19456;
          end
        end
        state <= 655;
      end
      655: begin  // instr 442 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 20480; c0 = c0 + 1) begin
          t0 = $signed(r555[a1]);
          r556[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 656;
      end
      656: begin  // instr 443 slice
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 4000; c2 = c2 + 1) begin
              t0 = $signed(r556[a1]);
              r557[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 + 96;
          end
        end
        state <= 657;
      end
      657: begin  // instr 444 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 4000; c2 = c2 + 1) begin
              t0 = $signed(r557[a1]);
              r558[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 16000;
        end
        state <= 658;
      end
      658: begin  // instr 445 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 4000; c2 = c2 + 1) begin
              t0 = $signed(r558[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r559[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 20000;
        end
        state <= 659;
      end
      659: begin  // instr 446 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          r560[a0] = t0[22:0];
          a0 = a0 + 1;
        end
        state <= 660;
      end
      660: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 4000; c2 = c2 + 1) begin
              t0 = $signed(r560[a0]);
              t1 = $signed(r559[a1]);
              t2 = t0 + t1;
              r560[a0] = t2[22:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 661;
      end
      661: begin  // instr 447 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r560[a1]);
            t1 = t0 << 2;
            r562[a0] = t1[24:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 5;
        end
        state <= 662;
      end
      662: begin  // instr 448 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 4000; c1 = c1 + 1) begin
            t0 = $signed(r456[a1]);
            t1 = t0 << 1;
            r563[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 4000;
        end
        state <= 663;
      end
      663: begin  // instr 449 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(rom1_c[a1]);
            t1 = t0;
            r564[a0] = t1[6:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 6;
        end
        state <= 664;
      end
      664: begin  // instr 450 rev
        a0 = 0;
        a1 = 5;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r564[a1]);
            r565[a0] = t0[6:0];
            a0 = a0 + 1;
            a1 = a1 - 1;
          end
          a1 = a1 + 12;
        end
        state <= 665;
      end
      665: begin  // instr 451 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6; c0 = c0 + 1) begin
          t0 = $signed(r565[a1]);
          r566[a0] = t0[6:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 666;
      end
      666: begin  // instr 452 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r567[a0] = t1[0:0];
        state <= 667;
      end
      667: begin  // instr 453 pad
        t0 = $signed(r567[0]);
        a0 = 0;
        for (c0 = 0; c0 < 4005; c0 = c0 + 1) begin
          r568[a0] = t0[8:0];
          a0 = a0 + 1;
        end
        state <= 668;
      end
      668: begin  // pad.scatter
        a0 = 5;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 4000; c1 = c1 + 1) begin
            t1 = $signed(r563[a1]);
            r568[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 5;
        end
        state <= 669;
      end
      669: begin  // instr 454 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r569[a0] = t1[0:0];
        state <= 670;
      end
      670: begin  // instr 455 pad
        t0 = $signed(r569[0]);
        a0 = 0;
        for (c0 = 0; c0 < 4101; c0 = c0 + 1) begin
          r570[a0] = t0[8:0];
          a0 = a0 + 1;
        end
        state <= 671;
      end
      671: begin  // pad.scatter
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 4005; c1 = c1 + 1) begin
            t1 = $signed(r568[a1]);
            r570[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 96;
        end
        state <= 672;
      end
      672: begin  // instr 456 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = a1;
          r571[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 673;
      end
      673: begin  // instr 457 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r571[a1]);
            r572[a0] = t0[10:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 674;
      end
      674: begin  // instr 458 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6; c0 = c0 + 1) begin
          t0 = a1;
          r573[a0] = t0[3:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 675;
      end
      675: begin  // instr 459 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r573[a1]);
            r574[a0] = t0[3:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 6;
        end
        state <= 676;
      end
      676: begin  // instr 460 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r572[a1]);
            t1 = $signed(r574[a2]);
            t2 = t0 + t1;
            r575[a0] = t2[11:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 1;
          a2 = a2 - 6;
        end
        state <= 677;
      end
      677: begin  // instr 461 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 4; c0 = c0 + 1) begin
          t0 = a1;
          r576[a0] = t0[2:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 678;
      end
      678: begin  // instr 462 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 4; c0 = c0 + 1) begin
          t0 = $signed(r576[a1]);
          t1 = t0 << 10;
          r577[a0] = t1[12:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 679;
      end
      679: begin  // instr 463 loop
        k15 = 0;
        o15x0 = 0;
        o15y0 = 0;
        state <= 680;
      end
      680: begin  // loop15.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 4101; c0 = c0 + 1) begin
          t0 = $signed(r570[a1]);
          r578[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 681;
      end
      681: begin  // loop15.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6144; c0 = c0 + 1) begin
          t0 = $signed(r575[a1]);
          r579[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 682;
      end
      682: begin  // loop15.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6; c0 = c0 + 1) begin
          t0 = $signed(r566[a1]);
          r580[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 683;
      end
      683: begin  // loop15.head
        if (k15 == 4) state <= 782;
        else state <= 684;
      end
      684: begin  // loop15.x0
        a0 = 0;
        a1 = o15x0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r577[a1]);
          r581[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 685;
      end
      685: begin  // instr 464 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r582[a0] = t2[1:0];
        state <= 686;
      end
      686: begin  // instr 465 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        t0 = (rom10_lit[a1] != 0);
        t1 = $signed(rom9_lit[a2]);
        t2 = $signed(r582[a3]);
        t3 = (t0 != 0) ? t2 : t1;
        r583[a0] = t3[0:0];
        state <= 687;
      end
      687: begin  // instr 466 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r581[a1]);
        t1 = $signed(rom9_lit[a2]);
        t2 = (t0 < t1) ? 1 : 0;
        r584[a0] = (t2 != 0);
        state <= 688;
      end
      688: begin  // instr 467 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r581[a1]);
        t1 = $signed(rom24_lit[a2]);
        t2 = t0 + t1;
        r586[a0] = t2[13:0];
        state <= 689;
      end
      689: begin  // instr 468 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        t0 = r584[a1];
        t1 = $signed(r581[a2]);
        t2 = $signed(r586[a3]);
        t3 = (t0 != 0) ? t2 : t1;
        r587[a0] = t3[12:0];
        state <= 690;
      end
      690: begin  // instr 469 dynamic_slice
        t9 = 0;
        t0 = $signed(r583[0]);
        t1 = (t0 < 0) ? 0 : t0;
        t1 = (t1 > 0) ? 0 : t1;
        t2 = t1;
        t2 = t2 + (t1 << 2);
        t2 = t2 + (t1 << 12);
        t9 = t9 + t2;
        t0 = $signed(r587[0]);
        t1 = (t0 < 0) ? 0 : t0;
        t1 = (t1 > 3072) ? 3072 : t1;
        t2 = t1;
        t9 = t9 + t2;
        a0 = 0;
        a1 = t9;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1029; c1 = c1 + 1) begin
            t0 = $signed(r578[a1]);
            r588[a0] = t0[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 + 3072;
        end
        state <= 691;
      end
      691: begin  // instr 470 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r579[a1]);
            t1 = $signed(rom9_lit[a2]);
            t2 = (t0 < t1) ? 1 : 0;
            r589[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 692;
      end
      692: begin  // instr 471 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r579[a1]);
            t1 = $signed(rom17_lit[a2]);
            t2 = t0 + t1;
            r590[a0] = t2[12:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 693;
      end
      693: begin  // instr 472 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = r589[a1];
            t1 = $signed(r579[a2]);
            t2 = $signed(r590[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r591[a0] = t3[11:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
        end
        state <= 694;
      end
      694: begin  // instr 473 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r591[a1]);
              r592[a0] = t0[11:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
        end
        state <= 695;
      end
      695: begin  // instr 474 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1024; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t9 = 0;
              t0 = $signed(r592[a2]);
              t1 = (t0 < 0) ? 0 : t0;
              t1 = (t1 > 1028) ? 1028 : t1;
              t2 = t1;
              t9 = t9 + t2;
              t3 = $signed(r588[a1 + t9]);
              r593[a0] = t3[8:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a1 = a1 + 1029;
          a2 = a2 - 6144;
        end
        state <= 696;
      end
      696: begin  // instr 475 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r593[a1]);
                r594[a0] = t0[8:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 697;
      end
      697: begin  // instr 476 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r580[a1]);
                t1 = $signed(r594[a2]);
                t2 = t0 + t1;
                r595[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 6;
            end
            a2 = a2 - 6144;
          end
        end
        state <= 698;
      end
      698: begin  // instr 477 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r596[a0] = t1[9:0];
        state <= 699;
      end
      699: begin  // instr 478 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r596[a1]);
                t1 = $signed(r595[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r597[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 6144;
          end
        end
        state <= 700;
      end
      700: begin  // instr 479 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r598[a0] = t1[9:0];
        state <= 701;
      end
      701: begin  // instr 480 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r598[a1]);
                t1 = $signed(r597[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r599[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 6144;
          end
        end
        state <= 702;
      end
      702: begin  // instr 481 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r580[a1]);
                t1 = $signed(r594[a2]);
                t2 = t0 - t1;
                r600[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 6;
            end
            a2 = a2 - 6144;
          end
        end
        state <= 703;
      end
      703: begin  // instr 482 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r601[a0] = t1[9:0];
        state <= 704;
      end
      704: begin  // instr 483 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r601[a1]);
                t1 = $signed(r600[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r602[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 6144;
          end
        end
        state <= 705;
      end
      705: begin  // instr 484 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r603[a0] = t1[9:0];
        state <= 706;
      end
      706: begin  // instr 485 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r603[a1]);
                t1 = $signed(r602[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r604[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 6144;
          end
        end
        state <= 707;
      end
      707: begin  // instr 486 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r599[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r605[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 708;
      end
      708: begin  // instr 487 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          r606[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 709;
      end
      709: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r606[a0]);
                t1 = $signed(r605[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r606[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 710;
      end
      710: begin  // instr 488 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r606[a1]);
              t1 = $signed(rom15_lit[a2]);
              t2 = t0 - t1;
              r607[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 711;
      end
      711: begin  // instr 489 loop
        k16 = 0;
        state <= 712;
      end
      712: begin  // loop16.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6144; c0 = c0 + 1) begin
          t0 = $signed(r599[a1]);
          r608[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 713;
      end
      713: begin  // loop16.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom15_lit[a1]);
          r609[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 714;
      end
      714: begin  // loop16.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r610[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 715;
      end
      715: begin  // loop16.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r607[a1]);
          r611[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 716;
      end
      716: begin  // loop16.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r606[a1]);
          r612[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 717;
      end
      717: begin  // loop16.head
        if (k16 == 12) state <= 740;
        else state <= 718;
      end
      718: begin  // instr 490 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r610[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r613[a0] = t2[4:0];
        state <= 719;
      end
      719: begin  // instr 491 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r611[a1]);
              t1 = $signed(r612[a2]);
              t2 = t0 + t1;
              r614[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
        end
        state <= 720;
      end
      720: begin  // instr 492 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r614[a1]);
              t1 = t0 >>> 1;
              r615[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 721;
      end
      721: begin  // instr 493 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r615[a1]);
                r616[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 722;
      end
      722: begin  // instr 494 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r608[a1]);
                t1 = $signed(r616[a2]);
                t2 = t0 - t1;
                r617[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 6144;
            a2 = a2 - 1024;
          end
        end
        state <= 723;
      end
      723: begin  // instr 495 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r617[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r618[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 724;
      end
      724: begin  // instr 496 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          r619[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 725;
      end
      725: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r619[a0]);
                t1 = $signed(r618[a1]);
                t2 = t0 + t1;
                r619[a0] = t2[13:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 726;
      end
      726: begin  // instr 497 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r608[a1]);
                t1 = 0 - t0;
                r620[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 727;
      end
      727: begin  // instr 498 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r615[a1]);
                r621[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 728;
      end
      728: begin  // instr 499 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r620[a1]);
                t1 = $signed(r621[a2]);
                t2 = t0 - t1;
                r622[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 6144;
            a2 = a2 - 1024;
          end
        end
        state <= 729;
      end
      729: begin  // instr 500 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r622[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r623[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 730;
      end
      730: begin  // instr 501 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          r624[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 731;
      end
      731: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r624[a0]);
                t1 = $signed(r623[a1]);
                t2 = t0 + t1;
                r624[a0] = t2[13:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 732;
      end
      732: begin  // instr 502 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r619[a1]);
              t1 = $signed(r624[a2]);
              t2 = t0 + t1;
              r625[a0] = t2[14:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
        end
        state <= 733;
      end
      733: begin  // instr 503 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r625[a1]);
              t1 = $signed(r609[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r626[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 734;
      end
      734: begin  // instr 504 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r626[a1];
              t1 = $signed(r611[a2]);
              t2 = $signed(r615[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r627[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
        end
        state <= 735;
      end
      735: begin  // instr 505 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r626[a1];
              t1 = $signed(r615[a2]);
              t2 = $signed(r612[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r628[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
        end
        state <= 736;
      end
      736: begin  // loop16.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r613[a1]);
          r610[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 737;
      end
      737: begin  // loop16.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r627[a1]);
          r611[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 738;
      end
      738: begin  // loop16.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r628[a1]);
          r612[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 739;
      end
      739: begin  // loop16.adv
        k16 = k16 + 1;
        state <= 717;
      end
      740: begin  // loop16.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r610[a1]);
          r629[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 741;
      end
      741: begin  // loop16.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r611[a1]);
          r630[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 742;
      end
      742: begin  // loop16.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r612[a1]);
          r631[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 743;
      end
      743: begin  // instr 506 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r604[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r632[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 744;
      end
      744: begin  // instr 507 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          r633[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 745;
      end
      745: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r633[a0]);
                t1 = $signed(r632[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r633[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 746;
      end
      746: begin  // instr 508 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r633[a1]);
              t1 = $signed(rom15_lit[a2]);
              t2 = t0 - t1;
              r634[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 747;
      end
      747: begin  // instr 509 loop
        k17 = 0;
        state <= 748;
      end
      748: begin  // loop17.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6144; c0 = c0 + 1) begin
          t0 = $signed(r604[a1]);
          r635[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 749;
      end
      749: begin  // loop17.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom15_lit[a1]);
          r636[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 750;
      end
      750: begin  // loop17.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r637[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 751;
      end
      751: begin  // loop17.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r634[a1]);
          r638[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 752;
      end
      752: begin  // loop17.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r633[a1]);
          r639[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 753;
      end
      753: begin  // loop17.head
        if (k17 == 12) state <= 776;
        else state <= 754;
      end
      754: begin  // instr 510 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r637[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r640[a0] = t2[4:0];
        state <= 755;
      end
      755: begin  // instr 511 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r638[a1]);
              t1 = $signed(r639[a2]);
              t2 = t0 + t1;
              r641[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
        end
        state <= 756;
      end
      756: begin  // instr 512 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r641[a1]);
              t1 = t0 >>> 1;
              r642[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 757;
      end
      757: begin  // instr 513 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r642[a1]);
                r643[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 758;
      end
      758: begin  // instr 514 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r635[a1]);
                t1 = $signed(r643[a2]);
                t2 = t0 - t1;
                r644[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 6144;
            a2 = a2 - 1024;
          end
        end
        state <= 759;
      end
      759: begin  // instr 515 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r644[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r645[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 760;
      end
      760: begin  // instr 516 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          r646[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 761;
      end
      761: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r646[a0]);
                t1 = $signed(r645[a1]);
                t2 = t0 + t1;
                r646[a0] = t2[13:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 762;
      end
      762: begin  // instr 517 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r635[a1]);
                t1 = 0 - t0;
                r647[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 763;
      end
      763: begin  // instr 518 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r642[a1]);
                r648[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 764;
      end
      764: begin  // instr 519 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r647[a1]);
                t1 = $signed(r648[a2]);
                t2 = t0 - t1;
                r649[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 6144;
            a2 = a2 - 1024;
          end
        end
        state <= 765;
      end
      765: begin  // instr 520 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r649[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r650[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 766;
      end
      766: begin  // instr 521 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          r651[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 767;
      end
      767: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r651[a0]);
                t1 = $signed(r650[a1]);
                t2 = t0 + t1;
                r651[a0] = t2[13:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 768;
      end
      768: begin  // instr 522 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r646[a1]);
              t1 = $signed(r651[a2]);
              t2 = t0 + t1;
              r652[a0] = t2[14:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
        end
        state <= 769;
      end
      769: begin  // instr 523 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r652[a1]);
              t1 = $signed(r636[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r653[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 770;
      end
      770: begin  // instr 524 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r653[a1];
              t1 = $signed(r638[a2]);
              t2 = $signed(r642[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r654[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
        end
        state <= 771;
      end
      771: begin  // instr 525 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r653[a1];
              t1 = $signed(r642[a2]);
              t2 = $signed(r639[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r655[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
        end
        state <= 772;
      end
      772: begin  // loop17.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r640[a1]);
          r637[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 773;
      end
      773: begin  // loop17.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r654[a1]);
          r638[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 774;
      end
      774: begin  // loop17.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r655[a1]);
          r639[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 775;
      end
      775: begin  // loop17.adv
        k17 = k17 + 1;
        state <= 753;
      end
      776: begin  // loop17.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r637[a1]);
          r656[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 777;
      end
      777: begin  // loop17.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r638[a1]);
          r657[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 778;
      end
      778: begin  // loop17.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r639[a1]);
          r658[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 779;
      end
      779: begin  // instr 526 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r631[a1]);
              t1 = $signed(r658[a2]);
              t2 = t0 - t1;
              r659[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
        end
        state <= 780;
      end
      780: begin  // loop15.y0
        a0 = o15y0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r659[a1]);
          r660[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 781;
      end
      781: begin  // loop15.adv
        k15 = k15 + 1;
        o15x0 = o15x0 + 1;
        o15y0 = o15y0 + 1024;
        state <= 683;
      end
      782: begin  // loop15.exit
        t0 = 0;
        state <= 783;
      end
      783: begin  // instr 527 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 4; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1024; c3 = c3 + 1) begin
                t0 = $signed(r660[a1]);
                r661[a0] = t0[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 3072;
          end
        end
        state <= 784;
      end
      784: begin  // instr 528 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 4096; c0 = c0 + 1) begin
          t0 = $signed(r661[a1]);
          r662[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 785;
      end
      785: begin  // instr 529 slice
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 4000; c2 = c2 + 1) begin
              t0 = $signed(r662[a1]);
              r663[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 + 96;
          end
        end
        state <= 786;
      end
      786: begin  // instr 530 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 4000; c2 = c2 + 1) begin
              t0 = $signed(r663[a1]);
              r664[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
        end
        state <= 787;
      end
      787: begin  // instr 531 slice
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 4000; c2 = c2 + 1) begin
              t0 = $signed(r664[a1]);
              r665[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
        end
        state <= 788;
      end
      788: begin  // instr 532 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 4000; c0 = c0 + 1) begin
          t0 = $signed(r665[a1]);
          r666[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 789;
      end
      789: begin  // instr 533 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 4000; c1 = c1 + 1) begin
            t0 = $signed(r666[a1]);
            t1 = t0 >>> 1;
            r667[a0] = t1[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 4000;
        end
        state <= 790;
      end
      790: begin  // instr 534 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom18_lit[a1]);
        t1 = t0;
        r668[a0] = t1[7:0];
        state <= 791;
      end
      791: begin  // instr 535 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 4000; c1 = c1 + 1) begin
            t0 = $signed(r668[a1]);
            t1 = $signed(r667[a2]);
            t2 = (t0 < t1) ? t1 : t0;
            r669[a0] = t2[9:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a2 = a2 - 4000;
        end
        state <= 792;
      end
      792: begin  // instr 536 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom19_lit[a1]);
        t1 = t0;
        r670[a0] = t1[7:0];
        state <= 793;
      end
      793: begin  // instr 537 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 4000; c1 = c1 + 1) begin
            t0 = $signed(r670[a1]);
            t1 = $signed(r669[a2]);
            t2 = (t1 < t0) ? t1 : t0;
            r671[a0] = t2[7:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a2 = a2 - 4000;
        end
        state <= 794;
      end
      794: begin  // instr 538 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 2000; c0 = c0 + 1) begin
          t0 = a1;
          r672[a0] = t0[11:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 795;
      end
      795: begin  // instr 539 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 2000; c0 = c0 + 1) begin
          t0 = $signed(r672[a1]);
          t1 = t0 << 1;
          r673[a0] = t1[12:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 796;
      end
      796: begin  // instr 540 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 2000; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          t1 = $signed(r673[a2]);
          t2 = t0 + t1;
          r674[a0] = t2[12:0];
          a0 = a0 + 1;
          a2 = a2 + 1;
        end
        state <= 797;
      end
      797: begin  // instr 541 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 2000; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r674[a1]);
            r675[a0] = t0[12:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 798;
      end
      798: begin  // instr 542 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 2000; c1 = c1 + 1) begin
            t9 = 0;
            t0 = $signed(r675[a2]);
            t1 = (t0 < 0) ? 0 : t0;
            t1 = (t1 > 3999) ? 3999 : t1;
            t2 = t1;
            t9 = t9 + t2;
            t3 = $signed(r671[a1 + t9]);
            r676[a0] = t3[7:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 4000;
          a2 = a2 - 2000;
        end
        state <= 799;
      end
      799: begin  // instr 543 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 2000; c1 = c1 + 1) begin
            t0 = $signed(r676[a1]);
            t1 = t0 << 1;
            r677[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 2000;
        end
        state <= 800;
      end
      800: begin  // instr 544 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(rom0_c[a1]);
            t1 = t0;
            r678[a0] = t1[5:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 801;
      end
      801: begin  // instr 545 rev
        a0 = 0;
        a1 = 15;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r678[a1]);
            r679[a0] = t0[5:0];
            a0 = a0 + 1;
            a1 = a1 - 1;
          end
          a1 = a1 + 32;
        end
        state <= 802;
      end
      802: begin  // instr 546 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r679[a1]);
          r680[a0] = t0[5:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 803;
      end
      803: begin  // instr 547 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r681[a0] = t1[0:0];
        state <= 804;
      end
      804: begin  // instr 548 pad
        t0 = $signed(r681[0]);
        a0 = 0;
        for (c0 = 0; c0 < 2015; c0 = c0 + 1) begin
          r682[a0] = t0[8:0];
          a0 = a0 + 1;
        end
        state <= 805;
      end
      805: begin  // pad.scatter
        a0 = 15;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 2000; c1 = c1 + 1) begin
            t1 = $signed(r677[a1]);
            r682[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 15;
        end
        state <= 806;
      end
      806: begin  // instr 549 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r683[a0] = t1[0:0];
        state <= 807;
      end
      807: begin  // instr 550 pad
        t0 = $signed(r683[0]);
        a0 = 0;
        for (c0 = 0; c0 < 2063; c0 = c0 + 1) begin
          r684[a0] = t0[8:0];
          a0 = a0 + 1;
        end
        state <= 808;
      end
      808: begin  // pad.scatter
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 2015; c1 = c1 + 1) begin
            t1 = $signed(r682[a1]);
            r684[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 48;
        end
        state <= 809;
      end
      809: begin  // instr 551 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = a1;
          r685[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 810;
      end
      810: begin  // instr 552 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r685[a1]);
            r686[a0] = t0[10:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 811;
      end
      811: begin  // instr 553 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 16; c0 = c0 + 1) begin
          t0 = a1;
          r687[a0] = t0[4:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 812;
      end
      812: begin  // instr 554 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r687[a1]);
            r688[a0] = t0[4:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 16;
        end
        state <= 813;
      end
      813: begin  // instr 555 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r686[a1]);
            t1 = $signed(r688[a2]);
            t2 = t0 + t1;
            r689[a0] = t2[11:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 1;
          a2 = a2 - 16;
        end
        state <= 814;
      end
      814: begin  // instr 556 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 2; c0 = c0 + 1) begin
          t0 = a1;
          r690[a0] = t0[1:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 815;
      end
      815: begin  // instr 557 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 2; c0 = c0 + 1) begin
          t0 = $signed(r690[a1]);
          t1 = t0 << 10;
          r691[a0] = t1[11:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 816;
      end
      816: begin  // instr 558 loop
        k18 = 0;
        o18x0 = 0;
        o18y0 = 0;
        state <= 817;
      end
      817: begin  // loop18.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 2063; c0 = c0 + 1) begin
          t0 = $signed(r684[a1]);
          r692[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 818;
      end
      818: begin  // loop18.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 16384; c0 = c0 + 1) begin
          t0 = $signed(r689[a1]);
          r693[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 819;
      end
      819: begin  // loop18.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r680[a1]);
          r694[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 820;
      end
      820: begin  // loop18.head
        if (k18 == 2) state <= 919;
        else state <= 821;
      end
      821: begin  // loop18.x0
        a0 = 0;
        a1 = o18x0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r691[a1]);
          r695[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 822;
      end
      822: begin  // instr 559 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r696[a0] = t2[1:0];
        state <= 823;
      end
      823: begin  // instr 560 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        t0 = (rom10_lit[a1] != 0);
        t1 = $signed(rom9_lit[a2]);
        t2 = $signed(r696[a3]);
        t3 = (t0 != 0) ? t2 : t1;
        r697[a0] = t3[0:0];
        state <= 824;
      end
      824: begin  // instr 561 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r695[a1]);
        t1 = $signed(rom9_lit[a2]);
        t2 = (t0 < t1) ? 1 : 0;
        r698[a0] = (t2 != 0);
        state <= 825;
      end
      825: begin  // instr 562 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r695[a1]);
        t1 = $signed(rom25_lit[a2]);
        t2 = t0 + t1;
        r700[a0] = t2[12:0];
        state <= 826;
      end
      826: begin  // instr 563 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        t0 = r698[a1];
        t1 = $signed(r695[a2]);
        t2 = $signed(r700[a3]);
        t3 = (t0 != 0) ? t2 : t1;
        r701[a0] = t3[11:0];
        state <= 827;
      end
      827: begin  // instr 564 dynamic_slice
        t9 = 0;
        t0 = $signed(r697[0]);
        t1 = (t0 < 0) ? 0 : t0;
        t1 = (t1 > 0) ? 0 : t1;
        t2 = t1;
        t2 = t2 + (t1 << 1);
        t2 = t2 + (t1 << 2);
        t2 = t2 + (t1 << 3);
        t2 = t2 + (t1 << 11);
        t9 = t9 + t2;
        t0 = $signed(r701[0]);
        t1 = (t0 < 0) ? 0 : t0;
        t1 = (t1 > 1024) ? 1024 : t1;
        t2 = t1;
        t9 = t9 + t2;
        a0 = 0;
        a1 = t9;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1039; c1 = c1 + 1) begin
            t0 = $signed(r692[a1]);
            r702[a0] = t0[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 + 1024;
        end
        state <= 828;
      end
      828: begin  // instr 565 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r693[a1]);
            t1 = $signed(rom9_lit[a2]);
            t2 = (t0 < t1) ? 1 : 0;
            r703[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 829;
      end
      829: begin  // instr 566 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r693[a1]);
            t1 = $signed(rom12_lit[a2]);
            t2 = t0 + t1;
            r704[a0] = t2[12:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 830;
      end
      830: begin  // instr 567 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = r703[a1];
            t1 = $signed(r693[a2]);
            t2 = $signed(r704[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r705[a0] = t3[11:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
        end
        state <= 831;
      end
      831: begin  // instr 568 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r705[a1]);
              r706[a0] = t0[11:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
        end
        state <= 832;
      end
      832: begin  // instr 569 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1024; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 16; c2 = c2 + 1) begin
              t9 = 0;
              t0 = $signed(r706[a2]);
              t1 = (t0 < 0) ? 0 : t0;
              t1 = (t1 > 1038) ? 1038 : t1;
              t2 = t1;
              t9 = t9 + t2;
              t3 = $signed(r702[a1 + t9]);
              r707[a0] = t3[8:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a1 = a1 + 1039;
          a2 = a2 - 16384;
        end
        state <= 833;
      end
      833: begin  // instr 570 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r707[a1]);
                r708[a0] = t0[8:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
        end
        state <= 834;
      end
      834: begin  // instr 571 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r694[a1]);
                t1 = $signed(r708[a2]);
                t2 = t0 + t1;
                r709[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 16;
            end
            a2 = a2 - 16384;
          end
          a1 = a1 + 16;
        end
        state <= 835;
      end
      835: begin  // instr 572 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r710[a0] = t1[9:0];
        state <= 836;
      end
      836: begin  // instr 573 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r710[a1]);
                t1 = $signed(r709[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r711[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 16384;
          end
          a2 = a2 + 16384;
        end
        state <= 837;
      end
      837: begin  // instr 574 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r712[a0] = t1[9:0];
        state <= 838;
      end
      838: begin  // instr 575 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r712[a1]);
                t1 = $signed(r711[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r713[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 16384;
          end
          a2 = a2 + 16384;
        end
        state <= 839;
      end
      839: begin  // instr 576 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r694[a1]);
                t1 = $signed(r708[a2]);
                t2 = t0 - t1;
                r714[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 16;
            end
            a2 = a2 - 16384;
          end
          a1 = a1 + 16;
        end
        state <= 840;
      end
      840: begin  // instr 577 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r715[a0] = t1[9:0];
        state <= 841;
      end
      841: begin  // instr 578 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r715[a1]);
                t1 = $signed(r714[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r716[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 16384;
          end
          a2 = a2 + 16384;
        end
        state <= 842;
      end
      842: begin  // instr 579 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r717[a0] = t1[9:0];
        state <= 843;
      end
      843: begin  // instr 580 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r717[a1]);
                t1 = $signed(r716[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r718[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 16384;
          end
          a2 = a2 + 16384;
        end
        state <= 844;
      end
      844: begin  // instr 581 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r713[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r719[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 845;
      end
      845: begin  // instr 582 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          r720[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 846;
      end
      846: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r720[a0]);
                t1 = $signed(r719[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r720[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 847;
      end
      847: begin  // instr 583 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r720[a1]);
              t1 = $signed(rom15_lit[a2]);
              t2 = t0 - t1;
              r721[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 848;
      end
      848: begin  // instr 584 loop
        k19 = 0;
        state <= 849;
      end
      849: begin  // loop19.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 81920; c0 = c0 + 1) begin
          t0 = $signed(r713[a1]);
          r722[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 850;
      end
      850: begin  // loop19.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom15_lit[a1]);
          r723[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 851;
      end
      851: begin  // loop19.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r724[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 852;
      end
      852: begin  // loop19.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r721[a1]);
          r725[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 853;
      end
      853: begin  // loop19.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r720[a1]);
          r726[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 854;
      end
      854: begin  // loop19.head
        if (k19 == 12) state <= 877;
        else state <= 855;
      end
      855: begin  // instr 585 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r724[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r727[a0] = t2[4:0];
        state <= 856;
      end
      856: begin  // instr 586 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r725[a1]);
              t1 = $signed(r726[a2]);
              t2 = t0 + t1;
              r728[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
        end
        state <= 857;
      end
      857: begin  // instr 587 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r728[a1]);
              t1 = t0 >>> 1;
              r729[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 858;
      end
      858: begin  // instr 588 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r729[a1]);
                r730[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 859;
      end
      859: begin  // instr 589 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r722[a1]);
                t1 = $signed(r730[a2]);
                t2 = t0 - t1;
                r731[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 16384;
            a2 = a2 - 1024;
          end
          a1 = a1 + 16384;
          a2 = a2 + 1024;
        end
        state <= 860;
      end
      860: begin  // instr 590 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r731[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r732[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 861;
      end
      861: begin  // instr 591 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          r733[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 862;
      end
      862: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r733[a0]);
                t1 = $signed(r732[a1]);
                t2 = t0 + t1;
                r733[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 863;
      end
      863: begin  // instr 592 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r722[a1]);
                t1 = 0 - t0;
                r734[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 864;
      end
      864: begin  // instr 593 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r729[a1]);
                r735[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 865;
      end
      865: begin  // instr 594 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r734[a1]);
                t1 = $signed(r735[a2]);
                t2 = t0 - t1;
                r736[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 16384;
            a2 = a2 - 1024;
          end
          a1 = a1 + 16384;
          a2 = a2 + 1024;
        end
        state <= 866;
      end
      866: begin  // instr 595 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r736[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r737[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 867;
      end
      867: begin  // instr 596 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          r738[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 868;
      end
      868: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r738[a0]);
                t1 = $signed(r737[a1]);
                t2 = t0 + t1;
                r738[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 869;
      end
      869: begin  // instr 597 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r733[a1]);
              t1 = $signed(r738[a2]);
              t2 = t0 + t1;
              r739[a0] = t2[15:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
        end
        state <= 870;
      end
      870: begin  // instr 598 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r739[a1]);
              t1 = $signed(r723[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r740[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 871;
      end
      871: begin  // instr 599 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r740[a1];
              t1 = $signed(r725[a2]);
              t2 = $signed(r729[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r741[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
          a3 = a3 + 1024;
        end
        state <= 872;
      end
      872: begin  // instr 600 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r740[a1];
              t1 = $signed(r729[a2]);
              t2 = $signed(r726[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r742[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
          a3 = a3 + 1024;
        end
        state <= 873;
      end
      873: begin  // loop19.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r727[a1]);
          r724[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 874;
      end
      874: begin  // loop19.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r741[a1]);
          r725[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 875;
      end
      875: begin  // loop19.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r742[a1]);
          r726[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 876;
      end
      876: begin  // loop19.adv
        k19 = k19 + 1;
        state <= 854;
      end
      877: begin  // loop19.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r724[a1]);
          r743[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 878;
      end
      878: begin  // loop19.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r725[a1]);
          r744[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 879;
      end
      879: begin  // loop19.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r726[a1]);
          r745[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 880;
      end
      880: begin  // instr 601 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r718[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r746[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 881;
      end
      881: begin  // instr 602 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          r747[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 882;
      end
      882: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r747[a0]);
                t1 = $signed(r746[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r747[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 883;
      end
      883: begin  // instr 603 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r747[a1]);
              t1 = $signed(rom15_lit[a2]);
              t2 = t0 - t1;
              r748[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 884;
      end
      884: begin  // instr 604 loop
        k20 = 0;
        state <= 885;
      end
      885: begin  // loop20.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 81920; c0 = c0 + 1) begin
          t0 = $signed(r718[a1]);
          r749[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 886;
      end
      886: begin  // loop20.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom15_lit[a1]);
          r750[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 887;
      end
      887: begin  // loop20.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r751[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 888;
      end
      888: begin  // loop20.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r748[a1]);
          r752[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 889;
      end
      889: begin  // loop20.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r747[a1]);
          r753[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 890;
      end
      890: begin  // loop20.head
        if (k20 == 12) state <= 913;
        else state <= 891;
      end
      891: begin  // instr 605 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r751[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r754[a0] = t2[4:0];
        state <= 892;
      end
      892: begin  // instr 606 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r752[a1]);
              t1 = $signed(r753[a2]);
              t2 = t0 + t1;
              r755[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
        end
        state <= 893;
      end
      893: begin  // instr 607 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r755[a1]);
              t1 = t0 >>> 1;
              r756[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 894;
      end
      894: begin  // instr 608 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r756[a1]);
                r757[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 895;
      end
      895: begin  // instr 609 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r749[a1]);
                t1 = $signed(r757[a2]);
                t2 = t0 - t1;
                r758[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 16384;
            a2 = a2 - 1024;
          end
          a1 = a1 + 16384;
          a2 = a2 + 1024;
        end
        state <= 896;
      end
      896: begin  // instr 610 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r758[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r759[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 897;
      end
      897: begin  // instr 611 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          r760[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 898;
      end
      898: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r760[a0]);
                t1 = $signed(r759[a1]);
                t2 = t0 + t1;
                r760[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 899;
      end
      899: begin  // instr 612 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r749[a1]);
                t1 = 0 - t0;
                r761[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 900;
      end
      900: begin  // instr 613 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r756[a1]);
                r762[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 901;
      end
      901: begin  // instr 614 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r761[a1]);
                t1 = $signed(r762[a2]);
                t2 = t0 - t1;
                r763[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 16384;
            a2 = a2 - 1024;
          end
          a1 = a1 + 16384;
          a2 = a2 + 1024;
        end
        state <= 902;
      end
      902: begin  // instr 615 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r763[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r764[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16384;
          end
          a1 = a1 + 16384;
        end
        state <= 903;
      end
      903: begin  // instr 616 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          r765[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 904;
      end
      904: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r765[a0]);
                t1 = $signed(r764[a1]);
                t2 = t0 + t1;
                r765[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 905;
      end
      905: begin  // instr 617 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r760[a1]);
              t1 = $signed(r765[a2]);
              t2 = t0 + t1;
              r766[a0] = t2[15:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
        end
        state <= 906;
      end
      906: begin  // instr 618 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r766[a1]);
              t1 = $signed(r750[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r767[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
          a1 = a1 + 1024;
        end
        state <= 907;
      end
      907: begin  // instr 619 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r767[a1];
              t1 = $signed(r752[a2]);
              t2 = $signed(r756[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r768[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
          a3 = a3 + 1024;
        end
        state <= 908;
      end
      908: begin  // instr 620 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r767[a1];
              t1 = $signed(r756[a2]);
              t2 = $signed(r753[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r769[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
          a3 = a3 + 1024;
        end
        state <= 909;
      end
      909: begin  // loop20.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r754[a1]);
          r751[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 910;
      end
      910: begin  // loop20.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r768[a1]);
          r752[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 911;
      end
      911: begin  // loop20.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r769[a1]);
          r753[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 912;
      end
      912: begin  // loop20.adv
        k20 = k20 + 1;
        state <= 890;
      end
      913: begin  // loop20.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r751[a1]);
          r770[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 914;
      end
      914: begin  // loop20.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r752[a1]);
          r771[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 915;
      end
      915: begin  // loop20.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r753[a1]);
          r772[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 916;
      end
      916: begin  // instr 621 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r745[a1]);
              t1 = $signed(r772[a2]);
              t2 = t0 - t1;
              r773[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
          a1 = a1 + 1024;
          a2 = a2 + 1024;
        end
        state <= 917;
      end
      917: begin  // loop18.y0
        a0 = o18y0;
        a1 = 0;
        for (c0 = 0; c0 < 5120; c0 = c0 + 1) begin
          t0 = $signed(r773[a1]);
          r774[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 918;
      end
      918: begin  // loop18.adv
        k18 = k18 + 1;
        o18x0 = o18x0 + 1;
        o18y0 = o18y0 + 5120;
        state <= 820;
      end
      919: begin  // loop18.exit
        t0 = 0;
        state <= 920;
      end
      920: begin  // instr 622 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 2; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1024; c3 = c3 + 1) begin
                t0 = $signed(r774[a1]);
                r775[a0] = t0[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a1 = a1 + 4096;
            end
            a1 = a1 - 9216;
          end
        end
        state <= 921;
      end
      921: begin  // instr 623 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10240; c0 = c0 + 1) begin
          t0 = $signed(r775[a1]);
          r776[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 922;
      end
      922: begin  // instr 624 slice
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 2000; c2 = c2 + 1) begin
              t0 = $signed(r776[a1]);
              r777[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 + 48;
          end
        end
        state <= 923;
      end
      923: begin  // instr 625 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 2000; c2 = c2 + 1) begin
              t0 = $signed(r777[a1]);
              r778[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 8000;
        end
        state <= 924;
      end
      924: begin  // instr 626 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 2000; c2 = c2 + 1) begin
              t0 = $signed(r778[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r779[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 10000;
        end
        state <= 925;
      end
      925: begin  // instr 627 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          r780[a0] = t0[21:0];
          a0 = a0 + 1;
        end
        state <= 926;
      end
      926: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 2000; c2 = c2 + 1) begin
              t0 = $signed(r780[a0]);
              t1 = $signed(r779[a1]);
              t2 = t0 + t1;
              r780[a0] = t2[21:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 927;
      end
      927: begin  // instr 628 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r780[a1]);
            t1 = t0 << 3;
            r782[a0] = t1[24:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 5;
        end
        state <= 928;
      end
      928: begin  // instr 629 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 2000; c1 = c1 + 1) begin
            t0 = $signed(r676[a1]);
            t1 = t0 << 1;
            r783[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 2000;
        end
        state <= 929;
      end
      929: begin  // instr 630 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(rom1_c[a1]);
            t1 = t0;
            r784[a0] = t1[6:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 6;
        end
        state <= 930;
      end
      930: begin  // instr 631 rev
        a0 = 0;
        a1 = 5;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r784[a1]);
            r785[a0] = t0[6:0];
            a0 = a0 + 1;
            a1 = a1 - 1;
          end
          a1 = a1 + 12;
        end
        state <= 931;
      end
      931: begin  // instr 632 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6; c0 = c0 + 1) begin
          t0 = $signed(r785[a1]);
          r786[a0] = t0[6:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 932;
      end
      932: begin  // instr 633 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r787[a0] = t1[0:0];
        state <= 933;
      end
      933: begin  // instr 634 pad
        t0 = $signed(r787[0]);
        a0 = 0;
        for (c0 = 0; c0 < 2005; c0 = c0 + 1) begin
          r788[a0] = t0[8:0];
          a0 = a0 + 1;
        end
        state <= 934;
      end
      934: begin  // pad.scatter
        a0 = 5;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 2000; c1 = c1 + 1) begin
            t1 = $signed(r783[a1]);
            r788[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 5;
        end
        state <= 935;
      end
      935: begin  // instr 635 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r789[a0] = t1[0:0];
        state <= 936;
      end
      936: begin  // instr 636 pad
        t0 = $signed(r789[0]);
        a0 = 0;
        for (c0 = 0; c0 < 2053; c0 = c0 + 1) begin
          r790[a0] = t0[8:0];
          a0 = a0 + 1;
        end
        state <= 937;
      end
      937: begin  // pad.scatter
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 2005; c1 = c1 + 1) begin
            t1 = $signed(r788[a1]);
            r790[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 48;
        end
        state <= 938;
      end
      938: begin  // instr 637 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = a1;
          r791[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 939;
      end
      939: begin  // instr 638 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r791[a1]);
            r792[a0] = t0[10:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 940;
      end
      940: begin  // instr 639 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6; c0 = c0 + 1) begin
          t0 = a1;
          r793[a0] = t0[3:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 941;
      end
      941: begin  // instr 640 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r793[a1]);
            r794[a0] = t0[3:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 6;
        end
        state <= 942;
      end
      942: begin  // instr 641 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r792[a1]);
            t1 = $signed(r794[a2]);
            t2 = t0 + t1;
            r795[a0] = t2[11:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 1;
          a2 = a2 - 6;
        end
        state <= 943;
      end
      943: begin  // instr 642 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 2; c0 = c0 + 1) begin
          t0 = a1;
          r796[a0] = t0[1:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 944;
      end
      944: begin  // instr 643 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 2; c0 = c0 + 1) begin
          t0 = $signed(r796[a1]);
          t1 = t0 << 10;
          r797[a0] = t1[11:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 945;
      end
      945: begin  // instr 644 loop
        k21 = 0;
        o21x0 = 0;
        o21y0 = 0;
        state <= 946;
      end
      946: begin  // loop21.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 2053; c0 = c0 + 1) begin
          t0 = $signed(r790[a1]);
          r798[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 947;
      end
      947: begin  // loop21.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6144; c0 = c0 + 1) begin
          t0 = $signed(r795[a1]);
          r799[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 948;
      end
      948: begin  // loop21.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6; c0 = c0 + 1) begin
          t0 = $signed(r786[a1]);
          r800[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 949;
      end
      949: begin  // loop21.head
        if (k21 == 2) state <= 1048;
        else state <= 950;
      end
      950: begin  // loop21.x0
        a0 = 0;
        a1 = o21x0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r797[a1]);
          r801[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 951;
      end
      951: begin  // instr 645 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r802[a0] = t2[1:0];
        state <= 952;
      end
      952: begin  // instr 646 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        t0 = (rom10_lit[a1] != 0);
        t1 = $signed(rom9_lit[a2]);
        t2 = $signed(r802[a3]);
        t3 = (t0 != 0) ? t2 : t1;
        r803[a0] = t3[0:0];
        state <= 953;
      end
      953: begin  // instr 647 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r801[a1]);
        t1 = $signed(rom9_lit[a2]);
        t2 = (t0 < t1) ? 1 : 0;
        r804[a0] = (t2 != 0);
        state <= 954;
      end
      954: begin  // instr 648 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r801[a1]);
        t1 = $signed(rom27_lit[a2]);
        t2 = t0 + t1;
        r806[a0] = t2[12:0];
        state <= 955;
      end
      955: begin  // instr 649 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        t0 = r804[a1];
        t1 = $signed(r801[a2]);
        t2 = $signed(r806[a3]);
        t3 = (t0 != 0) ? t2 : t1;
        r807[a0] = t3[11:0];
        state <= 956;
      end
      956: begin  // instr 650 dynamic_slice
        t9 = 0;
        t0 = $signed(r803[0]);
        t1 = (t0 < 0) ? 0 : t0;
        t1 = (t1 > 0) ? 0 : t1;
        t2 = t1;
        t2 = t2 + (t1 << 2);
        t2 = t2 + (t1 << 11);
        t9 = t9 + t2;
        t0 = $signed(r807[0]);
        t1 = (t0 < 0) ? 0 : t0;
        t1 = (t1 > 1024) ? 1024 : t1;
        t2 = t1;
        t9 = t9 + t2;
        a0 = 0;
        a1 = t9;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1029; c1 = c1 + 1) begin
            t0 = $signed(r798[a1]);
            r808[a0] = t0[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 + 1024;
        end
        state <= 957;
      end
      957: begin  // instr 651 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r799[a1]);
            t1 = $signed(rom9_lit[a2]);
            t2 = (t0 < t1) ? 1 : 0;
            r809[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 958;
      end
      958: begin  // instr 652 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r799[a1]);
            t1 = $signed(rom17_lit[a2]);
            t2 = t0 + t1;
            r810[a0] = t2[12:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 959;
      end
      959: begin  // instr 653 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = r809[a1];
            t1 = $signed(r799[a2]);
            t2 = $signed(r810[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r811[a0] = t3[11:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
        end
        state <= 960;
      end
      960: begin  // instr 654 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r811[a1]);
              r812[a0] = t0[11:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
        end
        state <= 961;
      end
      961: begin  // instr 655 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1024; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t9 = 0;
              t0 = $signed(r812[a2]);
              t1 = (t0 < 0) ? 0 : t0;
              t1 = (t1 > 1028) ? 1028 : t1;
              t2 = t1;
              t9 = t9 + t2;
              t3 = $signed(r808[a1 + t9]);
              r813[a0] = t3[8:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a1 = a1 + 1029;
          a2 = a2 - 6144;
        end
        state <= 962;
      end
      962: begin  // instr 656 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r813[a1]);
                r814[a0] = t0[8:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 963;
      end
      963: begin  // instr 657 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r800[a1]);
                t1 = $signed(r814[a2]);
                t2 = t0 + t1;
                r815[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 6;
            end
            a2 = a2 - 6144;
          end
        end
        state <= 964;
      end
      964: begin  // instr 658 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r816[a0] = t1[9:0];
        state <= 965;
      end
      965: begin  // instr 659 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r816[a1]);
                t1 = $signed(r815[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r817[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 6144;
          end
        end
        state <= 966;
      end
      966: begin  // instr 660 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r818[a0] = t1[9:0];
        state <= 967;
      end
      967: begin  // instr 661 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r818[a1]);
                t1 = $signed(r817[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r819[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 6144;
          end
        end
        state <= 968;
      end
      968: begin  // instr 662 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r800[a1]);
                t1 = $signed(r814[a2]);
                t2 = t0 - t1;
                r820[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 6;
            end
            a2 = a2 - 6144;
          end
        end
        state <= 969;
      end
      969: begin  // instr 663 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r821[a0] = t1[9:0];
        state <= 970;
      end
      970: begin  // instr 664 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r821[a1]);
                t1 = $signed(r820[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r822[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 6144;
          end
        end
        state <= 971;
      end
      971: begin  // instr 665 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r823[a0] = t1[9:0];
        state <= 972;
      end
      972: begin  // instr 666 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r823[a1]);
                t1 = $signed(r822[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r824[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 6144;
          end
        end
        state <= 973;
      end
      973: begin  // instr 667 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r819[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r825[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 974;
      end
      974: begin  // instr 668 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          r826[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 975;
      end
      975: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r826[a0]);
                t1 = $signed(r825[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r826[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 976;
      end
      976: begin  // instr 669 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r826[a1]);
              t1 = $signed(rom15_lit[a2]);
              t2 = t0 - t1;
              r827[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 977;
      end
      977: begin  // instr 670 loop
        k22 = 0;
        state <= 978;
      end
      978: begin  // loop22.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6144; c0 = c0 + 1) begin
          t0 = $signed(r819[a1]);
          r828[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 979;
      end
      979: begin  // loop22.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom15_lit[a1]);
          r829[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 980;
      end
      980: begin  // loop22.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r830[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 981;
      end
      981: begin  // loop22.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r827[a1]);
          r831[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 982;
      end
      982: begin  // loop22.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r826[a1]);
          r832[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 983;
      end
      983: begin  // loop22.head
        if (k22 == 12) state <= 1006;
        else state <= 984;
      end
      984: begin  // instr 671 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r830[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r833[a0] = t2[4:0];
        state <= 985;
      end
      985: begin  // instr 672 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r831[a1]);
              t1 = $signed(r832[a2]);
              t2 = t0 + t1;
              r834[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
        end
        state <= 986;
      end
      986: begin  // instr 673 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r834[a1]);
              t1 = t0 >>> 1;
              r835[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 987;
      end
      987: begin  // instr 674 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r835[a1]);
                r836[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 988;
      end
      988: begin  // instr 675 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r828[a1]);
                t1 = $signed(r836[a2]);
                t2 = t0 - t1;
                r837[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 6144;
            a2 = a2 - 1024;
          end
        end
        state <= 989;
      end
      989: begin  // instr 676 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r837[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r838[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 990;
      end
      990: begin  // instr 677 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          r839[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 991;
      end
      991: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r839[a0]);
                t1 = $signed(r838[a1]);
                t2 = t0 + t1;
                r839[a0] = t2[13:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 992;
      end
      992: begin  // instr 678 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r828[a1]);
                t1 = 0 - t0;
                r840[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 993;
      end
      993: begin  // instr 679 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r835[a1]);
                r841[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 994;
      end
      994: begin  // instr 680 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r840[a1]);
                t1 = $signed(r841[a2]);
                t2 = t0 - t1;
                r842[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 6144;
            a2 = a2 - 1024;
          end
        end
        state <= 995;
      end
      995: begin  // instr 681 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r842[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r843[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 996;
      end
      996: begin  // instr 682 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          r844[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 997;
      end
      997: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r844[a0]);
                t1 = $signed(r843[a1]);
                t2 = t0 + t1;
                r844[a0] = t2[13:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 998;
      end
      998: begin  // instr 683 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r839[a1]);
              t1 = $signed(r844[a2]);
              t2 = t0 + t1;
              r845[a0] = t2[14:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
        end
        state <= 999;
      end
      999: begin  // instr 684 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r845[a1]);
              t1 = $signed(r829[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r846[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 1000;
      end
      1000: begin  // instr 685 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r846[a1];
              t1 = $signed(r831[a2]);
              t2 = $signed(r835[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r847[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
        end
        state <= 1001;
      end
      1001: begin  // instr 686 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r846[a1];
              t1 = $signed(r835[a2]);
              t2 = $signed(r832[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r848[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
        end
        state <= 1002;
      end
      1002: begin  // loop22.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r833[a1]);
          r830[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1003;
      end
      1003: begin  // loop22.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r847[a1]);
          r831[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1004;
      end
      1004: begin  // loop22.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r848[a1]);
          r832[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1005;
      end
      1005: begin  // loop22.adv
        k22 = k22 + 1;
        state <= 983;
      end
      1006: begin  // loop22.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r830[a1]);
          r849[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1007;
      end
      1007: begin  // loop22.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r831[a1]);
          r850[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1008;
      end
      1008: begin  // loop22.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r832[a1]);
          r851[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1009;
      end
      1009: begin  // instr 687 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r824[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r852[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 1010;
      end
      1010: begin  // instr 688 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          r853[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 1011;
      end
      1011: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r853[a0]);
                t1 = $signed(r852[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r853[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1012;
      end
      1012: begin  // instr 689 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r853[a1]);
              t1 = $signed(rom15_lit[a2]);
              t2 = t0 - t1;
              r854[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 1013;
      end
      1013: begin  // instr 690 loop
        k23 = 0;
        state <= 1014;
      end
      1014: begin  // loop23.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6144; c0 = c0 + 1) begin
          t0 = $signed(r824[a1]);
          r855[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1015;
      end
      1015: begin  // loop23.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom15_lit[a1]);
          r856[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1016;
      end
      1016: begin  // loop23.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r857[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1017;
      end
      1017: begin  // loop23.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r854[a1]);
          r858[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1018;
      end
      1018: begin  // loop23.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r853[a1]);
          r859[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1019;
      end
      1019: begin  // loop23.head
        if (k23 == 12) state <= 1042;
        else state <= 1020;
      end
      1020: begin  // instr 691 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r857[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r860[a0] = t2[4:0];
        state <= 1021;
      end
      1021: begin  // instr 692 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r858[a1]);
              t1 = $signed(r859[a2]);
              t2 = t0 + t1;
              r861[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
        end
        state <= 1022;
      end
      1022: begin  // instr 693 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r861[a1]);
              t1 = t0 >>> 1;
              r862[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 1023;
      end
      1023: begin  // instr 694 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r862[a1]);
                r863[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 1024;
      end
      1024: begin  // instr 695 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r855[a1]);
                t1 = $signed(r863[a2]);
                t2 = t0 - t1;
                r864[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 6144;
            a2 = a2 - 1024;
          end
        end
        state <= 1025;
      end
      1025: begin  // instr 696 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r864[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r865[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 1026;
      end
      1026: begin  // instr 697 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          r866[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 1027;
      end
      1027: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r866[a0]);
                t1 = $signed(r865[a1]);
                t2 = t0 + t1;
                r866[a0] = t2[13:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1028;
      end
      1028: begin  // instr 698 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r855[a1]);
                t1 = 0 - t0;
                r867[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 1029;
      end
      1029: begin  // instr 699 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r862[a1]);
                r868[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 1030;
      end
      1030: begin  // instr 700 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r867[a1]);
                t1 = $signed(r868[a2]);
                t2 = t0 - t1;
                r869[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 6144;
            a2 = a2 - 1024;
          end
        end
        state <= 1031;
      end
      1031: begin  // instr 701 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r869[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r870[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6144;
          end
        end
        state <= 1032;
      end
      1032: begin  // instr 702 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          r871[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 1033;
      end
      1033: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r871[a0]);
                t1 = $signed(r870[a1]);
                t2 = t0 + t1;
                r871[a0] = t2[13:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1034;
      end
      1034: begin  // instr 703 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r866[a1]);
              t1 = $signed(r871[a2]);
              t2 = t0 + t1;
              r872[a0] = t2[14:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
        end
        state <= 1035;
      end
      1035: begin  // instr 704 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r872[a1]);
              t1 = $signed(r856[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r873[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1024;
          end
        end
        state <= 1036;
      end
      1036: begin  // instr 705 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r873[a1];
              t1 = $signed(r858[a2]);
              t2 = $signed(r862[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r874[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
        end
        state <= 1037;
      end
      1037: begin  // instr 706 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = r873[a1];
              t1 = $signed(r862[a2]);
              t2 = $signed(r859[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r875[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
            a3 = a3 - 1024;
          end
        end
        state <= 1038;
      end
      1038: begin  // loop23.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r860[a1]);
          r857[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1039;
      end
      1039: begin  // loop23.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r874[a1]);
          r858[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1040;
      end
      1040: begin  // loop23.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r875[a1]);
          r859[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1041;
      end
      1041: begin  // loop23.adv
        k23 = k23 + 1;
        state <= 1019;
      end
      1042: begin  // loop23.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r857[a1]);
          r876[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1043;
      end
      1043: begin  // loop23.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r858[a1]);
          r877[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1044;
      end
      1044: begin  // loop23.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r859[a1]);
          r878[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1045;
      end
      1045: begin  // instr 707 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1024; c2 = c2 + 1) begin
              t0 = $signed(r851[a1]);
              t1 = $signed(r878[a2]);
              t2 = t0 - t1;
              r879[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1024;
            a2 = a2 - 1024;
          end
        end
        state <= 1046;
      end
      1046: begin  // loop21.y0
        a0 = o21y0;
        a1 = 0;
        for (c0 = 0; c0 < 1024; c0 = c0 + 1) begin
          t0 = $signed(r879[a1]);
          r880[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1047;
      end
      1047: begin  // loop21.adv
        k21 = k21 + 1;
        o21x0 = o21x0 + 1;
        o21y0 = o21y0 + 1024;
        state <= 949;
      end
      1048: begin  // loop21.exit
        t0 = 0;
        state <= 1049;
      end
      1049: begin  // instr 708 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 2; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1024; c3 = c3 + 1) begin
                t0 = $signed(r880[a1]);
                r881[a0] = t0[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 1024;
          end
        end
        state <= 1050;
      end
      1050: begin  // instr 709 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 2048; c0 = c0 + 1) begin
          t0 = $signed(r881[a1]);
          r882[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1051;
      end
      1051: begin  // instr 710 slice
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 2000; c2 = c2 + 1) begin
              t0 = $signed(r882[a1]);
              r883[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 + 48;
          end
        end
        state <= 1052;
      end
      1052: begin  // instr 711 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 2000; c2 = c2 + 1) begin
              t0 = $signed(r883[a1]);
              r884[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
        end
        state <= 1053;
      end
      1053: begin  // instr 712 slice
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 2000; c2 = c2 + 1) begin
              t0 = $signed(r884[a1]);
              r885[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
        end
        state <= 1054;
      end
      1054: begin  // instr 713 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 2000; c0 = c0 + 1) begin
          t0 = $signed(r885[a1]);
          r886[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1055;
      end
      1055: begin  // instr 714 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 2000; c1 = c1 + 1) begin
            t0 = $signed(r886[a1]);
            t1 = t0 >>> 1;
            r887[a0] = t1[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 2000;
        end
        state <= 1056;
      end
      1056: begin  // instr 715 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom18_lit[a1]);
        t1 = t0;
        r888[a0] = t1[7:0];
        state <= 1057;
      end
      1057: begin  // instr 716 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 2000; c1 = c1 + 1) begin
            t0 = $signed(r888[a1]);
            t1 = $signed(r887[a2]);
            t2 = (t0 < t1) ? t1 : t0;
            r889[a0] = t2[9:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a2 = a2 - 2000;
        end
        state <= 1058;
      end
      1058: begin  // instr 717 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom19_lit[a1]);
        t1 = t0;
        r890[a0] = t1[7:0];
        state <= 1059;
      end
      1059: begin  // instr 718 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 2000; c1 = c1 + 1) begin
            t0 = $signed(r890[a1]);
            t1 = $signed(r889[a2]);
            t2 = (t1 < t0) ? t1 : t0;
            r891[a0] = t2[7:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a2 = a2 - 2000;
        end
        state <= 1060;
      end
      1060: begin  // instr 719 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          t0 = a1;
          r892[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1061;
      end
      1061: begin  // instr 720 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          t0 = $signed(r892[a1]);
          t1 = t0 << 1;
          r893[a0] = t1[11:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1062;
      end
      1062: begin  // instr 721 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          t1 = $signed(r893[a2]);
          t2 = t0 + t1;
          r894[a0] = t2[11:0];
          a0 = a0 + 1;
          a2 = a2 + 1;
        end
        state <= 1063;
      end
      1063: begin  // instr 722 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r894[a1]);
            r895[a0] = t0[11:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 1064;
      end
      1064: begin  // instr 723 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1000; c1 = c1 + 1) begin
            t9 = 0;
            t0 = $signed(r895[a2]);
            t1 = (t0 < 0) ? 0 : t0;
            t1 = (t1 > 1999) ? 1999 : t1;
            t2 = t1;
            t9 = t9 + t2;
            t3 = $signed(r891[a1 + t9]);
            r896[a0] = t3[7:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 2000;
          a2 = a2 - 1000;
        end
        state <= 1065;
      end
      1065: begin  // instr 724 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1000; c1 = c1 + 1) begin
            t0 = $signed(r896[a1]);
            t1 = t0 << 1;
            r897[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 1000;
        end
        state <= 1066;
      end
      1066: begin  // instr 725 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(rom0_c[a1]);
            t1 = t0;
            r898[a0] = t1[5:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 1067;
      end
      1067: begin  // instr 726 rev
        a0 = 0;
        a1 = 15;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r898[a1]);
            r899[a0] = t0[5:0];
            a0 = a0 + 1;
            a1 = a1 - 1;
          end
          a1 = a1 + 32;
        end
        state <= 1068;
      end
      1068: begin  // instr 727 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r899[a1]);
          r900[a0] = t0[5:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1069;
      end
      1069: begin  // instr 728 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r901[a0] = t1[0:0];
        state <= 1070;
      end
      1070: begin  // instr 729 pad
        t0 = $signed(r901[0]);
        a0 = 0;
        for (c0 = 0; c0 < 1015; c0 = c0 + 1) begin
          r902[a0] = t0[8:0];
          a0 = a0 + 1;
        end
        state <= 1071;
      end
      1071: begin  // pad.scatter
        a0 = 15;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1000; c1 = c1 + 1) begin
            t1 = $signed(r897[a1]);
            r902[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 15;
        end
        state <= 1072;
      end
      1072: begin  // instr 730 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          t0 = a1;
          r903[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1073;
      end
      1073: begin  // instr 731 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r903[a1]);
            r904[a0] = t0[10:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 1074;
      end
      1074: begin  // instr 732 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 16; c0 = c0 + 1) begin
          t0 = a1;
          r905[a0] = t0[4:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1075;
      end
      1075: begin  // instr 733 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r905[a1]);
            r906[a0] = t0[4:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 16;
        end
        state <= 1076;
      end
      1076: begin  // instr 734 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r904[a1]);
            t1 = $signed(r906[a2]);
            t2 = t0 + t1;
            r907[a0] = t2[10:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 1;
          a2 = a2 - 16;
        end
        state <= 1077;
      end
      1077: begin  // instr 735 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r907[a1]);
            t1 = $signed(rom9_lit[a2]);
            t2 = (t0 < t1) ? 1 : 0;
            r908[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 1078;
      end
      1078: begin  // instr 736 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r907[a1]);
            t1 = $signed(rom28_lit[a2]);
            t2 = t0 + t1;
            r910[a0] = t2[11:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 1079;
      end
      1079: begin  // instr 737 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = r908[a1];
            t1 = $signed(r907[a2]);
            t2 = $signed(r910[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r911[a0] = t3[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
        end
        state <= 1080;
      end
      1080: begin  // instr 738 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r911[a1]);
              r912[a0] = t0[10:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
        end
        state <= 1081;
      end
      1081: begin  // instr 739 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1000; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 16; c2 = c2 + 1) begin
              t9 = 0;
              t0 = $signed(r912[a2]);
              t1 = (t0 < 0) ? 0 : t0;
              t1 = (t1 > 1014) ? 1014 : t1;
              t2 = t1;
              t9 = t9 + t2;
              t3 = $signed(r902[a1 + t9]);
              r913[a0] = t3[8:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a1 = a1 + 1015;
          a2 = a2 - 16000;
        end
        state <= 1082;
      end
      1082: begin  // instr 740 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r913[a1]);
                r914[a0] = t0[8:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16000;
          end
        end
        state <= 1083;
      end
      1083: begin  // instr 741 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r900[a1]);
                t1 = $signed(r914[a2]);
                t2 = t0 + t1;
                r915[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 16;
            end
            a2 = a2 - 16000;
          end
          a1 = a1 + 16;
        end
        state <= 1084;
      end
      1084: begin  // instr 742 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r916[a0] = t1[9:0];
        state <= 1085;
      end
      1085: begin  // instr 743 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r916[a1]);
                t1 = $signed(r915[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r917[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 16000;
          end
          a2 = a2 + 16000;
        end
        state <= 1086;
      end
      1086: begin  // instr 744 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r918[a0] = t1[9:0];
        state <= 1087;
      end
      1087: begin  // instr 745 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r918[a1]);
                t1 = $signed(r917[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r919[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 16000;
          end
          a2 = a2 + 16000;
        end
        state <= 1088;
      end
      1088: begin  // instr 746 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r900[a1]);
                t1 = $signed(r914[a2]);
                t2 = t0 - t1;
                r920[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 16;
            end
            a2 = a2 - 16000;
          end
          a1 = a1 + 16;
        end
        state <= 1089;
      end
      1089: begin  // instr 747 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r921[a0] = t1[9:0];
        state <= 1090;
      end
      1090: begin  // instr 748 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r921[a1]);
                t1 = $signed(r920[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r922[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 16000;
          end
          a2 = a2 + 16000;
        end
        state <= 1091;
      end
      1091: begin  // instr 749 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r923[a0] = t1[9:0];
        state <= 1092;
      end
      1092: begin  // instr 750 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r923[a1]);
                t1 = $signed(r922[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r924[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 16000;
          end
          a2 = a2 + 16000;
        end
        state <= 1093;
      end
      1093: begin  // instr 751 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r919[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r925[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16000;
          end
          a1 = a1 + 16000;
        end
        state <= 1094;
      end
      1094: begin  // instr 752 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5000; c0 = c0 + 1) begin
          r926[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 1095;
      end
      1095: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r926[a0]);
                t1 = $signed(r925[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r926[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1096;
      end
      1096: begin  // instr 753 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r926[a1]);
              t1 = $signed(rom15_lit[a2]);
              t2 = t0 - t1;
              r927[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1000;
          end
          a1 = a1 + 1000;
        end
        state <= 1097;
      end
      1097: begin  // instr 754 loop
        k24 = 0;
        state <= 1098;
      end
      1098: begin  // loop24.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80000; c0 = c0 + 1) begin
          t0 = $signed(r919[a1]);
          r928[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1099;
      end
      1099: begin  // loop24.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom15_lit[a1]);
          r929[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1100;
      end
      1100: begin  // loop24.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r930[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1101;
      end
      1101: begin  // loop24.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5000; c0 = c0 + 1) begin
          t0 = $signed(r927[a1]);
          r931[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1102;
      end
      1102: begin  // loop24.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5000; c0 = c0 + 1) begin
          t0 = $signed(r926[a1]);
          r932[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1103;
      end
      1103: begin  // loop24.head
        if (k24 == 12) state <= 1126;
        else state <= 1104;
      end
      1104: begin  // instr 755 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r930[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r933[a0] = t2[4:0];
        state <= 1105;
      end
      1105: begin  // instr 756 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r931[a1]);
              t1 = $signed(r932[a2]);
              t2 = t0 + t1;
              r934[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1000;
            a2 = a2 - 1000;
          end
          a1 = a1 + 1000;
          a2 = a2 + 1000;
        end
        state <= 1106;
      end
      1106: begin  // instr 757 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r934[a1]);
              t1 = t0 >>> 1;
              r935[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1000;
          end
          a1 = a1 + 1000;
        end
        state <= 1107;
      end
      1107: begin  // instr 758 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r935[a1]);
                r936[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1000;
          end
          a1 = a1 + 1000;
        end
        state <= 1108;
      end
      1108: begin  // instr 759 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r928[a1]);
                t1 = $signed(r936[a2]);
                t2 = t0 - t1;
                r937[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 16000;
            a2 = a2 - 1000;
          end
          a1 = a1 + 16000;
          a2 = a2 + 1000;
        end
        state <= 1109;
      end
      1109: begin  // instr 760 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r937[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r938[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16000;
          end
          a1 = a1 + 16000;
        end
        state <= 1110;
      end
      1110: begin  // instr 761 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5000; c0 = c0 + 1) begin
          r939[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 1111;
      end
      1111: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r939[a0]);
                t1 = $signed(r938[a1]);
                t2 = t0 + t1;
                r939[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1112;
      end
      1112: begin  // instr 762 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r928[a1]);
                t1 = 0 - t0;
                r940[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16000;
          end
          a1 = a1 + 16000;
        end
        state <= 1113;
      end
      1113: begin  // instr 763 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r935[a1]);
                r941[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1000;
          end
          a1 = a1 + 1000;
        end
        state <= 1114;
      end
      1114: begin  // instr 764 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r940[a1]);
                t1 = $signed(r941[a2]);
                t2 = t0 - t1;
                r942[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 16000;
            a2 = a2 - 1000;
          end
          a1 = a1 + 16000;
          a2 = a2 + 1000;
        end
        state <= 1115;
      end
      1115: begin  // instr 765 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r942[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r943[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16000;
          end
          a1 = a1 + 16000;
        end
        state <= 1116;
      end
      1116: begin  // instr 766 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5000; c0 = c0 + 1) begin
          r944[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 1117;
      end
      1117: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r944[a0]);
                t1 = $signed(r943[a1]);
                t2 = t0 + t1;
                r944[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1118;
      end
      1118: begin  // instr 767 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r939[a1]);
              t1 = $signed(r944[a2]);
              t2 = t0 + t1;
              r945[a0] = t2[15:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1000;
            a2 = a2 - 1000;
          end
          a1 = a1 + 1000;
          a2 = a2 + 1000;
        end
        state <= 1119;
      end
      1119: begin  // instr 768 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r945[a1]);
              t1 = $signed(r929[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r946[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1000;
          end
          a1 = a1 + 1000;
        end
        state <= 1120;
      end
      1120: begin  // instr 769 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = r946[a1];
              t1 = $signed(r931[a2]);
              t2 = $signed(r935[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r947[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1000;
            a2 = a2 - 1000;
            a3 = a3 - 1000;
          end
          a1 = a1 + 1000;
          a2 = a2 + 1000;
          a3 = a3 + 1000;
        end
        state <= 1121;
      end
      1121: begin  // instr 770 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = r946[a1];
              t1 = $signed(r935[a2]);
              t2 = $signed(r932[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r948[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1000;
            a2 = a2 - 1000;
            a3 = a3 - 1000;
          end
          a1 = a1 + 1000;
          a2 = a2 + 1000;
          a3 = a3 + 1000;
        end
        state <= 1122;
      end
      1122: begin  // loop24.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r933[a1]);
          r930[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1123;
      end
      1123: begin  // loop24.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5000; c0 = c0 + 1) begin
          t0 = $signed(r947[a1]);
          r931[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1124;
      end
      1124: begin  // loop24.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5000; c0 = c0 + 1) begin
          t0 = $signed(r948[a1]);
          r932[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1125;
      end
      1125: begin  // loop24.adv
        k24 = k24 + 1;
        state <= 1103;
      end
      1126: begin  // loop24.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r930[a1]);
          r949[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1127;
      end
      1127: begin  // loop24.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5000; c0 = c0 + 1) begin
          t0 = $signed(r931[a1]);
          r950[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1128;
      end
      1128: begin  // loop24.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5000; c0 = c0 + 1) begin
          t0 = $signed(r932[a1]);
          r951[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1129;
      end
      1129: begin  // instr 771 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r924[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r952[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16000;
          end
          a1 = a1 + 16000;
        end
        state <= 1130;
      end
      1130: begin  // instr 772 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5000; c0 = c0 + 1) begin
          r953[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 1131;
      end
      1131: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r953[a0]);
                t1 = $signed(r952[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r953[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1132;
      end
      1132: begin  // instr 773 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r953[a1]);
              t1 = $signed(rom15_lit[a2]);
              t2 = t0 - t1;
              r954[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1000;
          end
          a1 = a1 + 1000;
        end
        state <= 1133;
      end
      1133: begin  // instr 774 loop
        k25 = 0;
        state <= 1134;
      end
      1134: begin  // loop25.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80000; c0 = c0 + 1) begin
          t0 = $signed(r924[a1]);
          r955[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1135;
      end
      1135: begin  // loop25.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom15_lit[a1]);
          r956[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1136;
      end
      1136: begin  // loop25.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r957[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1137;
      end
      1137: begin  // loop25.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5000; c0 = c0 + 1) begin
          t0 = $signed(r954[a1]);
          r958[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1138;
      end
      1138: begin  // loop25.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5000; c0 = c0 + 1) begin
          t0 = $signed(r953[a1]);
          r959[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1139;
      end
      1139: begin  // loop25.head
        if (k25 == 12) state <= 1162;
        else state <= 1140;
      end
      1140: begin  // instr 775 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r957[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r960[a0] = t2[4:0];
        state <= 1141;
      end
      1141: begin  // instr 776 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r958[a1]);
              t1 = $signed(r959[a2]);
              t2 = t0 + t1;
              r961[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1000;
            a2 = a2 - 1000;
          end
          a1 = a1 + 1000;
          a2 = a2 + 1000;
        end
        state <= 1142;
      end
      1142: begin  // instr 777 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r961[a1]);
              t1 = t0 >>> 1;
              r962[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1000;
          end
          a1 = a1 + 1000;
        end
        state <= 1143;
      end
      1143: begin  // instr 778 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r962[a1]);
                r963[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1000;
          end
          a1 = a1 + 1000;
        end
        state <= 1144;
      end
      1144: begin  // instr 779 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r955[a1]);
                t1 = $signed(r963[a2]);
                t2 = t0 - t1;
                r964[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 16000;
            a2 = a2 - 1000;
          end
          a1 = a1 + 16000;
          a2 = a2 + 1000;
        end
        state <= 1145;
      end
      1145: begin  // instr 780 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r964[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r965[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16000;
          end
          a1 = a1 + 16000;
        end
        state <= 1146;
      end
      1146: begin  // instr 781 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5000; c0 = c0 + 1) begin
          r966[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 1147;
      end
      1147: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r966[a0]);
                t1 = $signed(r965[a1]);
                t2 = t0 + t1;
                r966[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1148;
      end
      1148: begin  // instr 782 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r955[a1]);
                t1 = 0 - t0;
                r967[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16000;
          end
          a1 = a1 + 16000;
        end
        state <= 1149;
      end
      1149: begin  // instr 783 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r962[a1]);
                r968[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1000;
          end
          a1 = a1 + 1000;
        end
        state <= 1150;
      end
      1150: begin  // instr 784 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r967[a1]);
                t1 = $signed(r968[a2]);
                t2 = t0 - t1;
                r969[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 16000;
            a2 = a2 - 1000;
          end
          a1 = a1 + 16000;
          a2 = a2 + 1000;
        end
        state <= 1151;
      end
      1151: begin  // instr 785 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r969[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r970[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 16000;
          end
          a1 = a1 + 16000;
        end
        state <= 1152;
      end
      1152: begin  // instr 786 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5000; c0 = c0 + 1) begin
          r971[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 1153;
      end
      1153: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r971[a0]);
                t1 = $signed(r970[a1]);
                t2 = t0 + t1;
                r971[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1154;
      end
      1154: begin  // instr 787 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r966[a1]);
              t1 = $signed(r971[a2]);
              t2 = t0 + t1;
              r972[a0] = t2[15:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1000;
            a2 = a2 - 1000;
          end
          a1 = a1 + 1000;
          a2 = a2 + 1000;
        end
        state <= 1155;
      end
      1155: begin  // instr 788 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r972[a1]);
              t1 = $signed(r956[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r973[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1000;
          end
          a1 = a1 + 1000;
        end
        state <= 1156;
      end
      1156: begin  // instr 789 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = r973[a1];
              t1 = $signed(r958[a2]);
              t2 = $signed(r962[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r974[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1000;
            a2 = a2 - 1000;
            a3 = a3 - 1000;
          end
          a1 = a1 + 1000;
          a2 = a2 + 1000;
          a3 = a3 + 1000;
        end
        state <= 1157;
      end
      1157: begin  // instr 790 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = r973[a1];
              t1 = $signed(r962[a2]);
              t2 = $signed(r959[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r975[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1000;
            a2 = a2 - 1000;
            a3 = a3 - 1000;
          end
          a1 = a1 + 1000;
          a2 = a2 + 1000;
          a3 = a3 + 1000;
        end
        state <= 1158;
      end
      1158: begin  // loop25.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r960[a1]);
          r957[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1159;
      end
      1159: begin  // loop25.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5000; c0 = c0 + 1) begin
          t0 = $signed(r974[a1]);
          r958[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1160;
      end
      1160: begin  // loop25.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5000; c0 = c0 + 1) begin
          t0 = $signed(r975[a1]);
          r959[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1161;
      end
      1161: begin  // loop25.adv
        k25 = k25 + 1;
        state <= 1139;
      end
      1162: begin  // loop25.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r957[a1]);
          r976[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1163;
      end
      1163: begin  // loop25.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5000; c0 = c0 + 1) begin
          t0 = $signed(r958[a1]);
          r977[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1164;
      end
      1164: begin  // loop25.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5000; c0 = c0 + 1) begin
          t0 = $signed(r959[a1]);
          r978[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1165;
      end
      1165: begin  // instr 791 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r951[a1]);
              t1 = $signed(r978[a2]);
              t2 = t0 - t1;
              r979[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1000;
            a2 = a2 - 1000;
          end
          a1 = a1 + 1000;
          a2 = a2 + 1000;
        end
        state <= 1166;
      end
      1166: begin  // instr 792 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r979[a1]);
              r980[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 4000;
        end
        state <= 1167;
      end
      1167: begin  // instr 793 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r980[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r981[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 5000;
        end
        state <= 1168;
      end
      1168: begin  // instr 794 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          r982[a0] = t0[20:0];
          a0 = a0 + 1;
        end
        state <= 1169;
      end
      1169: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r982[a0]);
              t1 = $signed(r981[a1]);
              t2 = t0 + t1;
              r982[a0] = t2[20:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1170;
      end
      1170: begin  // instr 795 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r982[a1]);
            t1 = t0 << 4;
            r984[a0] = t1[24:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 5;
        end
        state <= 1171;
      end
      1171: begin  // instr 796 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1000; c1 = c1 + 1) begin
            t0 = $signed(r896[a1]);
            t1 = t0 << 1;
            r985[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 1000;
        end
        state <= 1172;
      end
      1172: begin  // instr 797 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(rom1_c[a1]);
            t1 = t0;
            r986[a0] = t1[6:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 6;
        end
        state <= 1173;
      end
      1173: begin  // instr 798 rev
        a0 = 0;
        a1 = 5;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r986[a1]);
            r987[a0] = t0[6:0];
            a0 = a0 + 1;
            a1 = a1 - 1;
          end
          a1 = a1 + 12;
        end
        state <= 1174;
      end
      1174: begin  // instr 799 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6; c0 = c0 + 1) begin
          t0 = $signed(r987[a1]);
          r988[a0] = t0[6:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1175;
      end
      1175: begin  // instr 800 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r989[a0] = t1[0:0];
        state <= 1176;
      end
      1176: begin  // instr 801 pad
        t0 = $signed(r989[0]);
        a0 = 0;
        for (c0 = 0; c0 < 1005; c0 = c0 + 1) begin
          r990[a0] = t0[8:0];
          a0 = a0 + 1;
        end
        state <= 1177;
      end
      1177: begin  // pad.scatter
        a0 = 5;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1000; c1 = c1 + 1) begin
            t1 = $signed(r985[a1]);
            r990[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 5;
        end
        state <= 1178;
      end
      1178: begin  // instr 802 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          t0 = a1;
          r991[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1179;
      end
      1179: begin  // instr 803 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r991[a1]);
            r992[a0] = t0[10:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 1180;
      end
      1180: begin  // instr 804 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6; c0 = c0 + 1) begin
          t0 = a1;
          r993[a0] = t0[3:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1181;
      end
      1181: begin  // instr 805 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r993[a1]);
            r994[a0] = t0[3:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 6;
        end
        state <= 1182;
      end
      1182: begin  // instr 806 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r992[a1]);
            t1 = $signed(r994[a2]);
            t2 = t0 + t1;
            r995[a0] = t2[10:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 1;
          a2 = a2 - 6;
        end
        state <= 1183;
      end
      1183: begin  // instr 807 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r995[a1]);
            t1 = $signed(rom9_lit[a2]);
            t2 = (t0 < t1) ? 1 : 0;
            r996[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 1184;
      end
      1184: begin  // instr 808 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r995[a1]);
            t1 = $signed(rom30_lit[a2]);
            t2 = t0 + t1;
            r998[a0] = t2[11:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 1185;
      end
      1185: begin  // instr 809 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = r996[a1];
            t1 = $signed(r995[a2]);
            t2 = $signed(r998[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r999[a0] = t3[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
        end
        state <= 1186;
      end
      1186: begin  // instr 810 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r999[a1]);
              r1000[a0] = t0[10:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
        end
        state <= 1187;
      end
      1187: begin  // instr 811 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1000; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t9 = 0;
              t0 = $signed(r1000[a2]);
              t1 = (t0 < 0) ? 0 : t0;
              t1 = (t1 > 1004) ? 1004 : t1;
              t2 = t1;
              t9 = t9 + t2;
              t3 = $signed(r990[a1 + t9]);
              r1001[a0] = t3[8:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a1 = a1 + 1005;
          a2 = a2 - 6000;
        end
        state <= 1188;
      end
      1188: begin  // instr 812 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r1001[a1]);
                r1002[a0] = t0[8:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6000;
          end
        end
        state <= 1189;
      end
      1189: begin  // instr 813 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r988[a1]);
                t1 = $signed(r1002[a2]);
                t2 = t0 + t1;
                r1003[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 6;
            end
            a2 = a2 - 6000;
          end
        end
        state <= 1190;
      end
      1190: begin  // instr 814 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r1004[a0] = t1[9:0];
        state <= 1191;
      end
      1191: begin  // instr 815 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r1004[a1]);
                t1 = $signed(r1003[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r1005[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 6000;
          end
        end
        state <= 1192;
      end
      1192: begin  // instr 816 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r1006[a0] = t1[9:0];
        state <= 1193;
      end
      1193: begin  // instr 817 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r1006[a1]);
                t1 = $signed(r1005[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r1007[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 6000;
          end
        end
        state <= 1194;
      end
      1194: begin  // instr 818 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r988[a1]);
                t1 = $signed(r1002[a2]);
                t2 = t0 - t1;
                r1008[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 6;
            end
            a2 = a2 - 6000;
          end
        end
        state <= 1195;
      end
      1195: begin  // instr 819 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r1009[a0] = t1[9:0];
        state <= 1196;
      end
      1196: begin  // instr 820 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r1009[a1]);
                t1 = $signed(r1008[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r1010[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 6000;
          end
        end
        state <= 1197;
      end
      1197: begin  // instr 821 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r1011[a0] = t1[9:0];
        state <= 1198;
      end
      1198: begin  // instr 822 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r1011[a1]);
                t1 = $signed(r1010[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r1012[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 6000;
          end
        end
        state <= 1199;
      end
      1199: begin  // instr 823 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r1007[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r1013[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6000;
          end
        end
        state <= 1200;
      end
      1200: begin  // instr 824 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          r1014[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 1201;
      end
      1201: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r1014[a0]);
                t1 = $signed(r1013[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r1014[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1202;
      end
      1202: begin  // instr 825 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r1014[a1]);
              t1 = $signed(rom15_lit[a2]);
              t2 = t0 - t1;
              r1015[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1000;
          end
        end
        state <= 1203;
      end
      1203: begin  // instr 826 loop
        k26 = 0;
        state <= 1204;
      end
      1204: begin  // loop26.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6000; c0 = c0 + 1) begin
          t0 = $signed(r1007[a1]);
          r1016[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1205;
      end
      1205: begin  // loop26.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom15_lit[a1]);
          r1017[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1206;
      end
      1206: begin  // loop26.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r1018[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1207;
      end
      1207: begin  // loop26.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          t0 = $signed(r1015[a1]);
          r1019[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1208;
      end
      1208: begin  // loop26.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          t0 = $signed(r1014[a1]);
          r1020[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1209;
      end
      1209: begin  // loop26.head
        if (k26 == 12) state <= 1232;
        else state <= 1210;
      end
      1210: begin  // instr 827 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r1018[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r1021[a0] = t2[4:0];
        state <= 1211;
      end
      1211: begin  // instr 828 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r1019[a1]);
              t1 = $signed(r1020[a2]);
              t2 = t0 + t1;
              r1022[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1000;
            a2 = a2 - 1000;
          end
        end
        state <= 1212;
      end
      1212: begin  // instr 829 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r1022[a1]);
              t1 = t0 >>> 1;
              r1023[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1000;
          end
        end
        state <= 1213;
      end
      1213: begin  // instr 830 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r1023[a1]);
                r1024[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1000;
          end
        end
        state <= 1214;
      end
      1214: begin  // instr 831 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r1016[a1]);
                t1 = $signed(r1024[a2]);
                t2 = t0 - t1;
                r1025[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 6000;
            a2 = a2 - 1000;
          end
        end
        state <= 1215;
      end
      1215: begin  // instr 832 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r1025[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r1026[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6000;
          end
        end
        state <= 1216;
      end
      1216: begin  // instr 833 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          r1027[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 1217;
      end
      1217: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r1027[a0]);
                t1 = $signed(r1026[a1]);
                t2 = t0 + t1;
                r1027[a0] = t2[13:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1218;
      end
      1218: begin  // instr 834 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r1016[a1]);
                t1 = 0 - t0;
                r1028[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6000;
          end
        end
        state <= 1219;
      end
      1219: begin  // instr 835 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r1023[a1]);
                r1029[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1000;
          end
        end
        state <= 1220;
      end
      1220: begin  // instr 836 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r1028[a1]);
                t1 = $signed(r1029[a2]);
                t2 = t0 - t1;
                r1030[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 6000;
            a2 = a2 - 1000;
          end
        end
        state <= 1221;
      end
      1221: begin  // instr 837 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r1030[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r1031[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6000;
          end
        end
        state <= 1222;
      end
      1222: begin  // instr 838 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          r1032[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 1223;
      end
      1223: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r1032[a0]);
                t1 = $signed(r1031[a1]);
                t2 = t0 + t1;
                r1032[a0] = t2[13:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1224;
      end
      1224: begin  // instr 839 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r1027[a1]);
              t1 = $signed(r1032[a2]);
              t2 = t0 + t1;
              r1033[a0] = t2[14:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1000;
            a2 = a2 - 1000;
          end
        end
        state <= 1225;
      end
      1225: begin  // instr 840 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r1033[a1]);
              t1 = $signed(r1017[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r1034[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1000;
          end
        end
        state <= 1226;
      end
      1226: begin  // instr 841 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = r1034[a1];
              t1 = $signed(r1019[a2]);
              t2 = $signed(r1023[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r1035[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1000;
            a2 = a2 - 1000;
            a3 = a3 - 1000;
          end
        end
        state <= 1227;
      end
      1227: begin  // instr 842 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = r1034[a1];
              t1 = $signed(r1023[a2]);
              t2 = $signed(r1020[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r1036[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1000;
            a2 = a2 - 1000;
            a3 = a3 - 1000;
          end
        end
        state <= 1228;
      end
      1228: begin  // loop26.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1021[a1]);
          r1018[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1229;
      end
      1229: begin  // loop26.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          t0 = $signed(r1035[a1]);
          r1019[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1230;
      end
      1230: begin  // loop26.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          t0 = $signed(r1036[a1]);
          r1020[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1231;
      end
      1231: begin  // loop26.adv
        k26 = k26 + 1;
        state <= 1209;
      end
      1232: begin  // loop26.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1018[a1]);
          r1037[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1233;
      end
      1233: begin  // loop26.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          t0 = $signed(r1019[a1]);
          r1038[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1234;
      end
      1234: begin  // loop26.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          t0 = $signed(r1020[a1]);
          r1039[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1235;
      end
      1235: begin  // instr 843 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r1012[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r1040[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6000;
          end
        end
        state <= 1236;
      end
      1236: begin  // instr 844 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          r1041[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 1237;
      end
      1237: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r1041[a0]);
                t1 = $signed(r1040[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r1041[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1238;
      end
      1238: begin  // instr 845 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r1041[a1]);
              t1 = $signed(rom15_lit[a2]);
              t2 = t0 - t1;
              r1042[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1000;
          end
        end
        state <= 1239;
      end
      1239: begin  // instr 846 loop
        k27 = 0;
        state <= 1240;
      end
      1240: begin  // loop27.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6000; c0 = c0 + 1) begin
          t0 = $signed(r1012[a1]);
          r1043[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1241;
      end
      1241: begin  // loop27.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom15_lit[a1]);
          r1044[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1242;
      end
      1242: begin  // loop27.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r1045[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1243;
      end
      1243: begin  // loop27.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          t0 = $signed(r1042[a1]);
          r1046[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1244;
      end
      1244: begin  // loop27.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          t0 = $signed(r1041[a1]);
          r1047[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1245;
      end
      1245: begin  // loop27.head
        if (k27 == 12) state <= 1268;
        else state <= 1246;
      end
      1246: begin  // instr 847 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r1045[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r1048[a0] = t2[4:0];
        state <= 1247;
      end
      1247: begin  // instr 848 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r1046[a1]);
              t1 = $signed(r1047[a2]);
              t2 = t0 + t1;
              r1049[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1000;
            a2 = a2 - 1000;
          end
        end
        state <= 1248;
      end
      1248: begin  // instr 849 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r1049[a1]);
              t1 = t0 >>> 1;
              r1050[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1000;
          end
        end
        state <= 1249;
      end
      1249: begin  // instr 850 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r1050[a1]);
                r1051[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1000;
          end
        end
        state <= 1250;
      end
      1250: begin  // instr 851 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r1043[a1]);
                t1 = $signed(r1051[a2]);
                t2 = t0 - t1;
                r1052[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 6000;
            a2 = a2 - 1000;
          end
        end
        state <= 1251;
      end
      1251: begin  // instr 852 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r1052[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r1053[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6000;
          end
        end
        state <= 1252;
      end
      1252: begin  // instr 853 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          r1054[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 1253;
      end
      1253: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r1054[a0]);
                t1 = $signed(r1053[a1]);
                t2 = t0 + t1;
                r1054[a0] = t2[13:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1254;
      end
      1254: begin  // instr 854 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r1043[a1]);
                t1 = 0 - t0;
                r1055[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6000;
          end
        end
        state <= 1255;
      end
      1255: begin  // instr 855 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r1050[a1]);
                r1056[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 1000;
          end
        end
        state <= 1256;
      end
      1256: begin  // instr 856 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r1055[a1]);
                t1 = $signed(r1056[a2]);
                t2 = t0 - t1;
                r1057[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 6000;
            a2 = a2 - 1000;
          end
        end
        state <= 1257;
      end
      1257: begin  // instr 857 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r1057[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r1058[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 6000;
          end
        end
        state <= 1258;
      end
      1258: begin  // instr 858 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          r1059[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 1259;
      end
      1259: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 6; c3 = c3 + 1) begin
                t0 = $signed(r1059[a0]);
                t1 = $signed(r1058[a1]);
                t2 = t0 + t1;
                r1059[a0] = t2[13:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1260;
      end
      1260: begin  // instr 859 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r1054[a1]);
              t1 = $signed(r1059[a2]);
              t2 = t0 + t1;
              r1060[a0] = t2[14:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1000;
            a2 = a2 - 1000;
          end
        end
        state <= 1261;
      end
      1261: begin  // instr 860 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r1060[a1]);
              t1 = $signed(r1044[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r1061[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 1000;
          end
        end
        state <= 1262;
      end
      1262: begin  // instr 861 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = r1061[a1];
              t1 = $signed(r1046[a2]);
              t2 = $signed(r1050[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r1062[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1000;
            a2 = a2 - 1000;
            a3 = a3 - 1000;
          end
        end
        state <= 1263;
      end
      1263: begin  // instr 862 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = r1061[a1];
              t1 = $signed(r1050[a2]);
              t2 = $signed(r1047[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r1063[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 1000;
            a2 = a2 - 1000;
            a3 = a3 - 1000;
          end
        end
        state <= 1264;
      end
      1264: begin  // loop27.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1048[a1]);
          r1045[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1265;
      end
      1265: begin  // loop27.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          t0 = $signed(r1062[a1]);
          r1046[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1266;
      end
      1266: begin  // loop27.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          t0 = $signed(r1063[a1]);
          r1047[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1267;
      end
      1267: begin  // loop27.adv
        k27 = k27 + 1;
        state <= 1245;
      end
      1268: begin  // loop27.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1045[a1]);
          r1064[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1269;
      end
      1269: begin  // loop27.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          t0 = $signed(r1046[a1]);
          r1065[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1270;
      end
      1270: begin  // loop27.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          t0 = $signed(r1047[a1]);
          r1066[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1271;
      end
      1271: begin  // instr 863 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r1039[a1]);
              t1 = $signed(r1066[a2]);
              t2 = t0 - t1;
              r1067[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 1000;
            a2 = a2 - 1000;
          end
        end
        state <= 1272;
      end
      1272: begin  // instr 864 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r1067[a1]);
              r1068[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
        end
        state <= 1273;
      end
      1273: begin  // instr 865 slice
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1000; c2 = c2 + 1) begin
              t0 = $signed(r1068[a1]);
              r1069[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
        end
        state <= 1274;
      end
      1274: begin  // instr 866 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1000; c0 = c0 + 1) begin
          t0 = $signed(r1069[a1]);
          r1070[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1275;
      end
      1275: begin  // instr 867 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1000; c1 = c1 + 1) begin
            t0 = $signed(r1070[a1]);
            t1 = t0 >>> 1;
            r1071[a0] = t1[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 1000;
        end
        state <= 1276;
      end
      1276: begin  // instr 868 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom18_lit[a1]);
        t1 = t0;
        r1072[a0] = t1[7:0];
        state <= 1277;
      end
      1277: begin  // instr 869 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1000; c1 = c1 + 1) begin
            t0 = $signed(r1072[a1]);
            t1 = $signed(r1071[a2]);
            t2 = (t0 < t1) ? t1 : t0;
            r1073[a0] = t2[9:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a2 = a2 - 1000;
        end
        state <= 1278;
      end
      1278: begin  // instr 870 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom19_lit[a1]);
        t1 = t0;
        r1074[a0] = t1[7:0];
        state <= 1279;
      end
      1279: begin  // instr 871 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1000; c1 = c1 + 1) begin
            t0 = $signed(r1074[a1]);
            t1 = $signed(r1073[a2]);
            t2 = (t1 < t0) ? t1 : t0;
            r1075[a0] = t2[7:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a2 = a2 - 1000;
        end
        state <= 1280;
      end
      1280: begin  // instr 872 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 500; c0 = c0 + 1) begin
          t0 = a1;
          r1076[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1281;
      end
      1281: begin  // instr 873 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 500; c0 = c0 + 1) begin
          t0 = $signed(r1076[a1]);
          t1 = t0 << 1;
          r1077[a0] = t1[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1282;
      end
      1282: begin  // instr 874 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 500; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          t1 = $signed(r1077[a2]);
          t2 = t0 + t1;
          r1078[a0] = t2[10:0];
          a0 = a0 + 1;
          a2 = a2 + 1;
        end
        state <= 1283;
      end
      1283: begin  // instr 875 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 500; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r1078[a1]);
            r1079[a0] = t0[10:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 1284;
      end
      1284: begin  // instr 876 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 500; c1 = c1 + 1) begin
            t9 = 0;
            t0 = $signed(r1079[a2]);
            t1 = (t0 < 0) ? 0 : t0;
            t1 = (t1 > 999) ? 999 : t1;
            t2 = t1;
            t9 = t9 + t2;
            t3 = $signed(r1075[a1 + t9]);
            r1080[a0] = t3[7:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 1000;
          a2 = a2 - 500;
        end
        state <= 1285;
      end
      1285: begin  // instr 877 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 500; c1 = c1 + 1) begin
            t0 = $signed(r1080[a1]);
            t1 = t0 << 1;
            r1081[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 500;
        end
        state <= 1286;
      end
      1286: begin  // instr 878 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(rom0_c[a1]);
            t1 = t0;
            r1082[a0] = t1[5:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 1287;
      end
      1287: begin  // instr 879 rev
        a0 = 0;
        a1 = 15;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r1082[a1]);
            r1083[a0] = t0[5:0];
            a0 = a0 + 1;
            a1 = a1 - 1;
          end
          a1 = a1 + 32;
        end
        state <= 1288;
      end
      1288: begin  // instr 880 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r1083[a1]);
          r1084[a0] = t0[5:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1289;
      end
      1289: begin  // instr 881 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r1085[a0] = t1[0:0];
        state <= 1290;
      end
      1290: begin  // instr 882 pad
        t0 = $signed(r1085[0]);
        a0 = 0;
        for (c0 = 0; c0 < 515; c0 = c0 + 1) begin
          r1086[a0] = t0[8:0];
          a0 = a0 + 1;
        end
        state <= 1291;
      end
      1291: begin  // pad.scatter
        a0 = 15;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 500; c1 = c1 + 1) begin
            t1 = $signed(r1081[a1]);
            r1086[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 15;
        end
        state <= 1292;
      end
      1292: begin  // instr 883 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 500; c0 = c0 + 1) begin
          t0 = a1;
          r1087[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1293;
      end
      1293: begin  // instr 884 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 500; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r1087[a1]);
            r1088[a0] = t0[9:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 1294;
      end
      1294: begin  // instr 885 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 16; c0 = c0 + 1) begin
          t0 = a1;
          r1089[a0] = t0[4:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1295;
      end
      1295: begin  // instr 886 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r1089[a1]);
            r1090[a0] = t0[4:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 16;
        end
        state <= 1296;
      end
      1296: begin  // instr 887 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 500; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r1088[a1]);
            t1 = $signed(r1090[a2]);
            t2 = t0 + t1;
            r1091[a0] = t2[10:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 1;
          a2 = a2 - 16;
        end
        state <= 1297;
      end
      1297: begin  // instr 888 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 500; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r1091[a1]);
            t1 = $signed(rom9_lit[a2]);
            t2 = (t0 < t1) ? 1 : 0;
            r1092[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 1298;
      end
      1298: begin  // instr 889 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 500; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r1091[a1]);
            t1 = $signed(rom31_lit[a2]);
            t2 = t0 + t1;
            r1094[a0] = t2[11:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 1299;
      end
      1299: begin  // instr 890 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 500; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = r1092[a1];
            t1 = $signed(r1091[a2]);
            t2 = $signed(r1094[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r1095[a0] = t3[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
        end
        state <= 1300;
      end
      1300: begin  // instr 891 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 500; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r1095[a1]);
              r1096[a0] = t0[10:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
        end
        state <= 1301;
      end
      1301: begin  // instr 892 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 500; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 16; c2 = c2 + 1) begin
              t9 = 0;
              t0 = $signed(r1096[a2]);
              t1 = (t0 < 0) ? 0 : t0;
              t1 = (t1 > 514) ? 514 : t1;
              t2 = t1;
              t9 = t9 + t2;
              t3 = $signed(r1086[a1 + t9]);
              r1097[a0] = t3[8:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a1 = a1 + 515;
          a2 = a2 - 8000;
        end
        state <= 1302;
      end
      1302: begin  // instr 893 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1097[a1]);
                r1098[a0] = t0[8:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 8000;
          end
        end
        state <= 1303;
      end
      1303: begin  // instr 894 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1084[a1]);
                t1 = $signed(r1098[a2]);
                t2 = t0 + t1;
                r1099[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 16;
            end
            a2 = a2 - 8000;
          end
          a1 = a1 + 16;
        end
        state <= 1304;
      end
      1304: begin  // instr 895 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r1100[a0] = t1[9:0];
        state <= 1305;
      end
      1305: begin  // instr 896 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1100[a1]);
                t1 = $signed(r1099[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r1101[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 8000;
          end
          a2 = a2 + 8000;
        end
        state <= 1306;
      end
      1306: begin  // instr 897 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r1102[a0] = t1[9:0];
        state <= 1307;
      end
      1307: begin  // instr 898 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1102[a1]);
                t1 = $signed(r1101[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r1103[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 8000;
          end
          a2 = a2 + 8000;
        end
        state <= 1308;
      end
      1308: begin  // instr 899 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1084[a1]);
                t1 = $signed(r1098[a2]);
                t2 = t0 - t1;
                r1104[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 16;
            end
            a2 = a2 - 8000;
          end
          a1 = a1 + 16;
        end
        state <= 1309;
      end
      1309: begin  // instr 900 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r1105[a0] = t1[9:0];
        state <= 1310;
      end
      1310: begin  // instr 901 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1105[a1]);
                t1 = $signed(r1104[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r1106[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 8000;
          end
          a2 = a2 + 8000;
        end
        state <= 1311;
      end
      1311: begin  // instr 902 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r1107[a0] = t1[9:0];
        state <= 1312;
      end
      1312: begin  // instr 903 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1107[a1]);
                t1 = $signed(r1106[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r1108[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 8000;
          end
          a2 = a2 + 8000;
        end
        state <= 1313;
      end
      1313: begin  // instr 904 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1103[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r1109[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 8000;
          end
          a1 = a1 + 8000;
        end
        state <= 1314;
      end
      1314: begin  // instr 905 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 2500; c0 = c0 + 1) begin
          r1110[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 1315;
      end
      1315: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1110[a0]);
                t1 = $signed(r1109[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r1110[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1316;
      end
      1316: begin  // instr 906 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              t0 = $signed(r1110[a1]);
              t1 = $signed(rom15_lit[a2]);
              t2 = t0 - t1;
              r1111[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 500;
          end
          a1 = a1 + 500;
        end
        state <= 1317;
      end
      1317: begin  // instr 907 loop
        k28 = 0;
        state <= 1318;
      end
      1318: begin  // loop28.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 40000; c0 = c0 + 1) begin
          t0 = $signed(r1103[a1]);
          r1112[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1319;
      end
      1319: begin  // loop28.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom15_lit[a1]);
          r1113[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1320;
      end
      1320: begin  // loop28.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r1114[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1321;
      end
      1321: begin  // loop28.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 2500; c0 = c0 + 1) begin
          t0 = $signed(r1111[a1]);
          r1115[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1322;
      end
      1322: begin  // loop28.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 2500; c0 = c0 + 1) begin
          t0 = $signed(r1110[a1]);
          r1116[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1323;
      end
      1323: begin  // loop28.head
        if (k28 == 12) state <= 1346;
        else state <= 1324;
      end
      1324: begin  // instr 908 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r1114[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r1117[a0] = t2[4:0];
        state <= 1325;
      end
      1325: begin  // instr 909 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              t0 = $signed(r1115[a1]);
              t1 = $signed(r1116[a2]);
              t2 = t0 + t1;
              r1118[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 500;
            a2 = a2 - 500;
          end
          a1 = a1 + 500;
          a2 = a2 + 500;
        end
        state <= 1326;
      end
      1326: begin  // instr 910 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              t0 = $signed(r1118[a1]);
              t1 = t0 >>> 1;
              r1119[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 500;
          end
          a1 = a1 + 500;
        end
        state <= 1327;
      end
      1327: begin  // instr 911 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r1119[a1]);
                r1120[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 500;
          end
          a1 = a1 + 500;
        end
        state <= 1328;
      end
      1328: begin  // instr 912 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1112[a1]);
                t1 = $signed(r1120[a2]);
                t2 = t0 - t1;
                r1121[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 8000;
            a2 = a2 - 500;
          end
          a1 = a1 + 8000;
          a2 = a2 + 500;
        end
        state <= 1329;
      end
      1329: begin  // instr 913 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1121[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r1122[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 8000;
          end
          a1 = a1 + 8000;
        end
        state <= 1330;
      end
      1330: begin  // instr 914 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 2500; c0 = c0 + 1) begin
          r1123[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 1331;
      end
      1331: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1123[a0]);
                t1 = $signed(r1122[a1]);
                t2 = t0 + t1;
                r1123[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1332;
      end
      1332: begin  // instr 915 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1112[a1]);
                t1 = 0 - t0;
                r1124[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 8000;
          end
          a1 = a1 + 8000;
        end
        state <= 1333;
      end
      1333: begin  // instr 916 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r1119[a1]);
                r1125[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 500;
          end
          a1 = a1 + 500;
        end
        state <= 1334;
      end
      1334: begin  // instr 917 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1124[a1]);
                t1 = $signed(r1125[a2]);
                t2 = t0 - t1;
                r1126[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 8000;
            a2 = a2 - 500;
          end
          a1 = a1 + 8000;
          a2 = a2 + 500;
        end
        state <= 1335;
      end
      1335: begin  // instr 918 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1126[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r1127[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 8000;
          end
          a1 = a1 + 8000;
        end
        state <= 1336;
      end
      1336: begin  // instr 919 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 2500; c0 = c0 + 1) begin
          r1128[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 1337;
      end
      1337: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1128[a0]);
                t1 = $signed(r1127[a1]);
                t2 = t0 + t1;
                r1128[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1338;
      end
      1338: begin  // instr 920 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              t0 = $signed(r1123[a1]);
              t1 = $signed(r1128[a2]);
              t2 = t0 + t1;
              r1129[a0] = t2[15:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 500;
            a2 = a2 - 500;
          end
          a1 = a1 + 500;
          a2 = a2 + 500;
        end
        state <= 1339;
      end
      1339: begin  // instr 921 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              t0 = $signed(r1129[a1]);
              t1 = $signed(r1113[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r1130[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 500;
          end
          a1 = a1 + 500;
        end
        state <= 1340;
      end
      1340: begin  // instr 922 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              t0 = r1130[a1];
              t1 = $signed(r1115[a2]);
              t2 = $signed(r1119[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r1131[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 500;
            a2 = a2 - 500;
            a3 = a3 - 500;
          end
          a1 = a1 + 500;
          a2 = a2 + 500;
          a3 = a3 + 500;
        end
        state <= 1341;
      end
      1341: begin  // instr 923 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              t0 = r1130[a1];
              t1 = $signed(r1119[a2]);
              t2 = $signed(r1116[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r1132[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 500;
            a2 = a2 - 500;
            a3 = a3 - 500;
          end
          a1 = a1 + 500;
          a2 = a2 + 500;
          a3 = a3 + 500;
        end
        state <= 1342;
      end
      1342: begin  // loop28.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1117[a1]);
          r1114[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1343;
      end
      1343: begin  // loop28.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 2500; c0 = c0 + 1) begin
          t0 = $signed(r1131[a1]);
          r1115[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1344;
      end
      1344: begin  // loop28.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 2500; c0 = c0 + 1) begin
          t0 = $signed(r1132[a1]);
          r1116[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1345;
      end
      1345: begin  // loop28.adv
        k28 = k28 + 1;
        state <= 1323;
      end
      1346: begin  // loop28.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1114[a1]);
          r1133[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1347;
      end
      1347: begin  // loop28.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 2500; c0 = c0 + 1) begin
          t0 = $signed(r1115[a1]);
          r1134[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1348;
      end
      1348: begin  // loop28.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 2500; c0 = c0 + 1) begin
          t0 = $signed(r1116[a1]);
          r1135[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1349;
      end
      1349: begin  // instr 924 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1108[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r1136[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 8000;
          end
          a1 = a1 + 8000;
        end
        state <= 1350;
      end
      1350: begin  // instr 925 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 2500; c0 = c0 + 1) begin
          r1137[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 1351;
      end
      1351: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1137[a0]);
                t1 = $signed(r1136[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r1137[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1352;
      end
      1352: begin  // instr 926 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              t0 = $signed(r1137[a1]);
              t1 = $signed(rom15_lit[a2]);
              t2 = t0 - t1;
              r1138[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 500;
          end
          a1 = a1 + 500;
        end
        state <= 1353;
      end
      1353: begin  // instr 927 loop
        k29 = 0;
        state <= 1354;
      end
      1354: begin  // loop29.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 40000; c0 = c0 + 1) begin
          t0 = $signed(r1108[a1]);
          r1139[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1355;
      end
      1355: begin  // loop29.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom15_lit[a1]);
          r1140[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1356;
      end
      1356: begin  // loop29.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r1141[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1357;
      end
      1357: begin  // loop29.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 2500; c0 = c0 + 1) begin
          t0 = $signed(r1138[a1]);
          r1142[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1358;
      end
      1358: begin  // loop29.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 2500; c0 = c0 + 1) begin
          t0 = $signed(r1137[a1]);
          r1143[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1359;
      end
      1359: begin  // loop29.head
        if (k29 == 12) state <= 1382;
        else state <= 1360;
      end
      1360: begin  // instr 928 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r1141[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r1144[a0] = t2[4:0];
        state <= 1361;
      end
      1361: begin  // instr 929 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              t0 = $signed(r1142[a1]);
              t1 = $signed(r1143[a2]);
              t2 = t0 + t1;
              r1145[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 500;
            a2 = a2 - 500;
          end
          a1 = a1 + 500;
          a2 = a2 + 500;
        end
        state <= 1362;
      end
      1362: begin  // instr 930 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              t0 = $signed(r1145[a1]);
              t1 = t0 >>> 1;
              r1146[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 500;
          end
          a1 = a1 + 500;
        end
        state <= 1363;
      end
      1363: begin  // instr 931 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r1146[a1]);
                r1147[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 500;
          end
          a1 = a1 + 500;
        end
        state <= 1364;
      end
      1364: begin  // instr 932 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1139[a1]);
                t1 = $signed(r1147[a2]);
                t2 = t0 - t1;
                r1148[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 8000;
            a2 = a2 - 500;
          end
          a1 = a1 + 8000;
          a2 = a2 + 500;
        end
        state <= 1365;
      end
      1365: begin  // instr 933 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1148[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r1149[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 8000;
          end
          a1 = a1 + 8000;
        end
        state <= 1366;
      end
      1366: begin  // instr 934 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 2500; c0 = c0 + 1) begin
          r1150[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 1367;
      end
      1367: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1150[a0]);
                t1 = $signed(r1149[a1]);
                t2 = t0 + t1;
                r1150[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1368;
      end
      1368: begin  // instr 935 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1139[a1]);
                t1 = 0 - t0;
                r1151[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 8000;
          end
          a1 = a1 + 8000;
        end
        state <= 1369;
      end
      1369: begin  // instr 936 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r1146[a1]);
                r1152[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 500;
          end
          a1 = a1 + 500;
        end
        state <= 1370;
      end
      1370: begin  // instr 937 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1151[a1]);
                t1 = $signed(r1152[a2]);
                t2 = t0 - t1;
                r1153[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 8000;
            a2 = a2 - 500;
          end
          a1 = a1 + 8000;
          a2 = a2 + 500;
        end
        state <= 1371;
      end
      1371: begin  // instr 938 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1153[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r1154[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 8000;
          end
          a1 = a1 + 8000;
        end
        state <= 1372;
      end
      1372: begin  // instr 939 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 2500; c0 = c0 + 1) begin
          r1155[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 1373;
      end
      1373: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1155[a0]);
                t1 = $signed(r1154[a1]);
                t2 = t0 + t1;
                r1155[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1374;
      end
      1374: begin  // instr 940 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              t0 = $signed(r1150[a1]);
              t1 = $signed(r1155[a2]);
              t2 = t0 + t1;
              r1156[a0] = t2[15:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 500;
            a2 = a2 - 500;
          end
          a1 = a1 + 500;
          a2 = a2 + 500;
        end
        state <= 1375;
      end
      1375: begin  // instr 941 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              t0 = $signed(r1156[a1]);
              t1 = $signed(r1140[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r1157[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 500;
          end
          a1 = a1 + 500;
        end
        state <= 1376;
      end
      1376: begin  // instr 942 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              t0 = r1157[a1];
              t1 = $signed(r1142[a2]);
              t2 = $signed(r1146[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r1158[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 500;
            a2 = a2 - 500;
            a3 = a3 - 500;
          end
          a1 = a1 + 500;
          a2 = a2 + 500;
          a3 = a3 + 500;
        end
        state <= 1377;
      end
      1377: begin  // instr 943 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              t0 = r1157[a1];
              t1 = $signed(r1146[a2]);
              t2 = $signed(r1143[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r1159[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 500;
            a2 = a2 - 500;
            a3 = a3 - 500;
          end
          a1 = a1 + 500;
          a2 = a2 + 500;
          a3 = a3 + 500;
        end
        state <= 1378;
      end
      1378: begin  // loop29.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1144[a1]);
          r1141[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1379;
      end
      1379: begin  // loop29.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 2500; c0 = c0 + 1) begin
          t0 = $signed(r1158[a1]);
          r1142[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1380;
      end
      1380: begin  // loop29.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 2500; c0 = c0 + 1) begin
          t0 = $signed(r1159[a1]);
          r1143[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1381;
      end
      1381: begin  // loop29.adv
        k29 = k29 + 1;
        state <= 1359;
      end
      1382: begin  // loop29.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1141[a1]);
          r1160[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1383;
      end
      1383: begin  // loop29.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 2500; c0 = c0 + 1) begin
          t0 = $signed(r1142[a1]);
          r1161[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1384;
      end
      1384: begin  // loop29.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 2500; c0 = c0 + 1) begin
          t0 = $signed(r1143[a1]);
          r1162[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1385;
      end
      1385: begin  // instr 944 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              t0 = $signed(r1135[a1]);
              t1 = $signed(r1162[a2]);
              t2 = t0 - t1;
              r1163[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 500;
            a2 = a2 - 500;
          end
          a1 = a1 + 500;
          a2 = a2 + 500;
        end
        state <= 1386;
      end
      1386: begin  // instr 945 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              t0 = $signed(r1163[a1]);
              r1164[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 2000;
        end
        state <= 1387;
      end
      1387: begin  // instr 946 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              t0 = $signed(r1164[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r1165[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 2500;
        end
        state <= 1388;
      end
      1388: begin  // instr 947 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          r1166[a0] = t0[19:0];
          a0 = a0 + 1;
        end
        state <= 1389;
      end
      1389: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 500; c2 = c2 + 1) begin
              t0 = $signed(r1166[a0]);
              t1 = $signed(r1165[a1]);
              t2 = t0 + t1;
              r1166[a0] = t2[19:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1390;
      end
      1390: begin  // instr 948 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r1166[a1]);
            t1 = t0 << 5;
            r1168[a0] = t1[24:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 5;
        end
        state <= 1391;
      end
      1391: begin  // instr 949 concat
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r120[a1]);
            r1169[a0] = t0[24:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 25;
        end
        state <= 1392;
      end
      1392: begin  // concat
        a0 = 5;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r342[a1]);
            r1169[a0] = t0[24:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 25;
        end
        state <= 1393;
      end
      1393: begin  // concat
        a0 = 10;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r562[a1]);
            r1169[a0] = t0[24:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 25;
        end
        state <= 1394;
      end
      1394: begin  // concat
        a0 = 15;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r782[a1]);
            r1169[a0] = t0[24:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 25;
        end
        state <= 1395;
      end
      1395: begin  // concat
        a0 = 20;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r984[a1]);
            r1169[a0] = t0[24:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 25;
        end
        state <= 1396;
      end
      1396: begin  // concat
        a0 = 25;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r1168[a1]);
            r1169[a0] = t0[24:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 25;
        end
        state <= 1397;
      end
      1397: begin  // instr 950 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(rom2_c[a1]);
          t1 = t0;
          r1170[a0] = t1[0:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1398;
      end
      1398: begin  // instr 951 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1170[a1]);
            r1171[a0] = t0[0:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1399;
      end
      1399: begin  // instr 952 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1169[a1]);
            t1 = $signed(r1171[a2]);
            t2 = t0 - t1;
            r1172[a0] = t2[24:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 30;
        end
        state <= 1400;
      end
      1400: begin  // instr 953 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(rom3_c[a1]);
          t1 = t0;
          r1173[a0] = t1[2:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1401;
      end
      1401: begin  // instr 954 ge
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(r1173[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 >= t1) ? 1 : 0;
          r1174[a0] = (t2 != 0);
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1402;
      end
      1402: begin  // instr 955 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(r1173[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 < t1) ? t1 : t0;
          r1175[a0] = t2[0:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1403;
      end
      1403: begin  // instr 956 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1175[a1]);
            r1176[a0] = t0[0:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1404;
      end
      1404: begin  // instr 957 shl
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1172[a1]);
            t1 = $signed(r1176[a2]);
            t2 = t0 << t1;
            r1177[a0] = t2[24:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 30;
        end
        state <= 1405;
      end
      1405: begin  // instr 958 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(r1173[a1]);
          t1 = 0 - t0;
          r1178[a0] = t1[2:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1406;
      end
      1406: begin  // instr 959 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(r1178[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 < t1) ? t1 : t0;
          r1179[a0] = t2[2:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1407;
      end
      1407: begin  // instr 960 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1179[a1]);
            r1180[a0] = t0[2:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1408;
      end
      1408: begin  // instr 961 shra
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1172[a1]);
            t1 = $signed(r1180[a2]);
            t2 = t0 >>> t1;
            r1181[a0] = t2[21:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 30;
        end
        state <= 1409;
      end
      1409: begin  // instr 962 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = r1174[a1];
            r1182[a0] = (t0 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1410;
      end
      1410: begin  // instr 963 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = r1182[a1];
            t1 = $signed(r1181[a2]);
            t2 = $signed(r1177[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r1183[a0] = t3[21:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 30;
          a3 = a3 - 30;
        end
        state <= 1411;
      end
      1411: begin  // instr 964 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(rom4_c[a1]);
          t1 = t0;
          r1184[a0] = t1[2:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1412;
      end
      1412: begin  // instr 965 ge
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(r1184[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 >= t1) ? 1 : 0;
          r1185[a0] = (t2 != 0);
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1413;
      end
      1413: begin  // instr 966 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(r1184[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 < t1) ? t1 : t0;
          r1186[a0] = t2[0:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1414;
      end
      1414: begin  // instr 967 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1186[a1]);
            r1187[a0] = t0[0:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1415;
      end
      1415: begin  // instr 968 shl
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1172[a1]);
            t1 = $signed(r1187[a2]);
            t2 = t0 << t1;
            r1188[a0] = t2[24:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 30;
        end
        state <= 1416;
      end
      1416: begin  // instr 969 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(r1184[a1]);
          t1 = 0 - t0;
          r1189[a0] = t1[3:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1417;
      end
      1417: begin  // instr 970 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(r1189[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 < t1) ? t1 : t0;
          r1190[a0] = t2[3:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1418;
      end
      1418: begin  // instr 971 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1190[a1]);
            r1191[a0] = t0[3:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1419;
      end
      1419: begin  // instr 972 shra
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1172[a1]);
            t1 = $signed(r1191[a2]);
            t2 = t0 >>> t1;
            r1192[a0] = t2[20:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 30;
        end
        state <= 1420;
      end
      1420: begin  // instr 973 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = r1185[a1];
            r1193[a0] = (t0 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1421;
      end
      1421: begin  // instr 974 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = r1193[a1];
            t1 = $signed(r1192[a2]);
            t2 = $signed(r1188[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r1194[a0] = t3[21:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 30;
          a3 = a3 - 30;
        end
        state <= 1422;
      end
      1422: begin  // instr 975 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(rom2_c[a1]);
          t1 = t0;
          r1195[a0] = t1[0:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1423;
      end
      1423: begin  // instr 976 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(r1195[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 > t1) ? 1 : 0;
          r1196[a0] = (t2 != 0);
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1424;
      end
      1424: begin  // instr 977 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1183[a1]);
            t1 = $signed(r1194[a2]);
            t2 = t0 + t1;
            r1197[a0] = t2[22:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 30;
        end
        state <= 1425;
      end
      1425: begin  // instr 978 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(r1195[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 < t1) ? 1 : 0;
          r1198[a0] = (t2 != 0);
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1426;
      end
      1426: begin  // instr 979 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1183[a1]);
            t1 = $signed(r1194[a2]);
            t2 = t0 - t1;
            r1199[a0] = t2[21:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 30;
        end
        state <= 1427;
      end
      1427: begin  // instr 980 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = r1198[a1];
            r1200[a0] = (t0 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1428;
      end
      1428: begin  // instr 981 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = r1200[a1];
            t1 = $signed(r1183[a2]);
            t2 = $signed(r1199[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r1201[a0] = t3[21:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 30;
          a3 = a3 - 30;
        end
        state <= 1429;
      end
      1429: begin  // instr 982 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = r1196[a1];
            r1202[a0] = (t0 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1430;
      end
      1430: begin  // instr 983 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = r1202[a1];
            t1 = $signed(r1201[a2]);
            t2 = $signed(r1197[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r1203[a0] = t3[21:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 30;
          a3 = a3 - 30;
        end
        state <= 1431;
      end
      1431: begin  // instr 984 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom18_lit[a1]);
        t1 = t0;
        r1204[a0] = t1[7:0];
        state <= 1432;
      end
      1432: begin  // instr 985 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1204[a1]);
            t1 = $signed(r1203[a2]);
            t2 = (t0 < t1) ? t1 : t0;
            r1205[a0] = t2[21:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a2 = a2 - 30;
        end
        state <= 1433;
      end
      1433: begin  // instr 986 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom19_lit[a1]);
        t1 = t0;
        r1206[a0] = t1[7:0];
        state <= 1434;
      end
      1434: begin  // instr 987 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1206[a1]);
            t1 = $signed(r1205[a2]);
            t2 = (t1 < t0) ? t1 : t0;
            r1207[a0] = t2[7:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a2 = a2 - 30;
        end
        state <= 1435;
      end
      1435: begin  // instr 988 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1207[a1]);
            t1 = t0 << 1;
            r1208[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1436;
      end
      1436: begin  // instr 989 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r1208[a1]);
              r1209[a0] = t0[8:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1437;
      end
      1437: begin  // instr 990 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r1208[a1]);
              r1210[a0] = t0[8:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1438;
      end
      1438: begin  // instr 991 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r1210[a1]);
              t1 = 0 - t0;
              r1211[a0] = t1[8:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1439;
      end
      1439: begin  // instr 992 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(rom5_c[a1]);
            t1 = t0;
            r1212[a0] = t1[5:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 1440;
      end
      1440: begin  // instr 993 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(rom6_c[a1]);
            t1 = t0;
            r1213[a0] = t1[5:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 1441;
      end
      1441: begin  // instr 994 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1212[a1]);
              r1214[a0] = t0[5:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 300;
        end
        state <= 1442;
      end
      1442: begin  // instr 995 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1214[a1]);
              t1 = $signed(r1209[a2]);
              t2 = t0 + t1;
              r1215[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 300;
          a2 = a2 - 30;
        end
        state <= 1443;
      end
      1443: begin  // instr 996 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r1216[a0] = t1[9:0];
        state <= 1444;
      end
      1444: begin  // instr 997 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1216[a1]);
              t1 = $signed(r1215[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r1217[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 300;
        end
        state <= 1445;
      end
      1445: begin  // instr 998 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r1218[a0] = t1[9:0];
        state <= 1446;
      end
      1446: begin  // instr 999 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1218[a1]);
              t1 = $signed(r1217[a2]);
              t2 = (t1 < t0) ? t1 : t0;
              r1219[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 300;
        end
        state <= 1447;
      end
      1447: begin  // instr 1000 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1213[a1]);
              r1220[a0] = t0[5:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 300;
        end
        state <= 1448;
      end
      1448: begin  // instr 1001 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1220[a1]);
              t1 = $signed(r1211[a2]);
              t2 = t0 + t1;
              r1221[a0] = t2[8:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 300;
          a2 = a2 - 30;
        end
        state <= 1449;
      end
      1449: begin  // instr 1002 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r1222[a0] = t1[9:0];
        state <= 1450;
      end
      1450: begin  // instr 1003 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1222[a1]);
              t1 = $signed(r1221[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r1223[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 300;
        end
        state <= 1451;
      end
      1451: begin  // instr 1004 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r1224[a0] = t1[9:0];
        state <= 1452;
      end
      1452: begin  // instr 1005 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1224[a1]);
              t1 = $signed(r1223[a2]);
              t2 = (t1 < t0) ? t1 : t0;
              r1225[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 300;
        end
        state <= 1453;
      end
      1453: begin  // instr 1006 concat
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1219[a1]);
              r1226[a0] = t0[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a0 = a0 + 300;
        end
        state <= 1454;
      end
      1454: begin  // concat
        a0 = 300;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1225[a1]);
              r1226[a0] = t0[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a0 = a0 + 300;
        end
        state <= 1455;
      end
      1455: begin  // instr 1007 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(rom7_c[a1]);
          t1 = t0;
          r1227[a0] = t1[0:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1456;
      end
      1456: begin  // instr 1008 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1227[a1]);
              r1228[a0] = t0[0:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 10;
          end
        end
        state <= 1457;
      end
      1457: begin  // instr 1009 concat
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 60; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1226[a1]);
              r1229[a0] = t0[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a0 = a0 + 10;
        end
        state <= 1458;
      end
      1458: begin  // concat
        a0 = 600;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1228[a1]);
              r1229[a0] = t0[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a0 = a0 + 600;
        end
        state <= 1459;
      end
      1459: begin  // instr 1010 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 61; c2 = c2 + 1) begin
              t0 = $signed(r1229[a1]);
              r1230[a0] = t0[9:0];
              a0 = a0 + 1;
              a1 = a1 + 10;
            end
            a1 = a1 - 609;
          end
          a1 = a1 + 600;
        end
        state <= 1460;
      end
      1460: begin  // instr 1011 reduce_max
        t0 = -254;
        a0 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          r1231[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 1461;
      end
      1461: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 61; c2 = c2 + 1) begin
              t0 = $signed(r1231[a0]);
              t1 = $signed(r1230[a1]);
              t2 = (t0 < t1) ? t1 : t0;
              r1231[a0] = t2[9:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1462;
      end
      1462: begin  // instr 1012 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1231[a1]);
            t1 = $signed(rom33_lit[a2]);
            t2 = t0 - t1;
            r1233[a0] = t2[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1463;
      end
      1463: begin  // instr 1013 loop
        k30 = 0;
        state <= 1464;
      end
      1464: begin  // loop30.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 610; c0 = c0 + 1) begin
          t0 = $signed(r1230[a1]);
          r1234[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1465;
      end
      1465: begin  // loop30.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom33_lit[a1]);
          r1235[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1466;
      end
      1466: begin  // loop30.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r1236[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1467;
      end
      1467: begin  // loop30.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1233[a1]);
          r1237[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1468;
      end
      1468: begin  // loop30.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1231[a1]);
          r1238[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1469;
      end
      1469: begin  // loop30.head
        if (k30 == 11) state <= 1485;
        else state <= 1470;
      end
      1470: begin  // instr 1014 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r1236[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r1239[a0] = t2[4:0];
        state <= 1471;
      end
      1471: begin  // instr 1015 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1237[a1]);
            t1 = $signed(r1238[a2]);
            t2 = t0 + t1;
            r1240[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
        end
        state <= 1472;
      end
      1472: begin  // instr 1016 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1240[a1]);
            t1 = t0 >>> 1;
            r1241[a0] = t1[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1473;
      end
      1473: begin  // instr 1017 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r1241[a1]);
              r1242[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1474;
      end
      1474: begin  // instr 1018 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 61; c2 = c2 + 1) begin
              t0 = $signed(r1234[a1]);
              t1 = $signed(r1242[a2]);
              t2 = t0 - t1;
              r1243[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 610;
          a2 = a2 - 10;
        end
        state <= 1475;
      end
      1475: begin  // instr 1019 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 61; c2 = c2 + 1) begin
              t0 = $signed(r1243[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r1244[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 610;
        end
        state <= 1476;
      end
      1476: begin  // instr 1020 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          r1245[a0] = t0[16:0];
          a0 = a0 + 1;
        end
        state <= 1477;
      end
      1477: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 61; c2 = c2 + 1) begin
              t0 = $signed(r1245[a0]);
              t1 = $signed(r1244[a1]);
              t2 = t0 + t1;
              r1245[a0] = t2[16:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1478;
      end
      1478: begin  // instr 1021 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1245[a1]);
            t1 = $signed(r1235[a2]);
            t2 = (t0 > t1) ? 1 : 0;
            r1246[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1479;
      end
      1479: begin  // instr 1022 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = r1246[a1];
            t1 = $signed(r1237[a2]);
            t2 = $signed(r1241[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r1247[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
          a3 = a3 - 10;
        end
        state <= 1480;
      end
      1480: begin  // instr 1023 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = r1246[a1];
            t1 = $signed(r1241[a2]);
            t2 = $signed(r1238[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r1248[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
          a3 = a3 - 10;
        end
        state <= 1481;
      end
      1481: begin  // loop30.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1239[a1]);
          r1236[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1482;
      end
      1482: begin  // loop30.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1247[a1]);
          r1237[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1483;
      end
      1483: begin  // loop30.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1248[a1]);
          r1238[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1484;
      end
      1484: begin  // loop30.adv
        k30 = k30 + 1;
        state <= 1469;
      end
      1485: begin  // loop30.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1236[a1]);
          r1249[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1486;
      end
      1486: begin  // loop30.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1237[a1]);
          r1250[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1487;
      end
      1487: begin  // loop30.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1238[a1]);
          r1251[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1488;
      end
      1488: begin  // instr 1024 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1213[a1]);
              r1252[a0] = t0[5:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 300;
        end
        state <= 1489;
      end
      1489: begin  // instr 1025 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1252[a1]);
              t1 = $signed(r1209[a2]);
              t2 = t0 + t1;
              r1253[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 300;
          a2 = a2 - 30;
        end
        state <= 1490;
      end
      1490: begin  // instr 1026 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r1254[a0] = t1[9:0];
        state <= 1491;
      end
      1491: begin  // instr 1027 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1254[a1]);
              t1 = $signed(r1253[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r1255[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 300;
        end
        state <= 1492;
      end
      1492: begin  // instr 1028 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r1256[a0] = t1[9:0];
        state <= 1493;
      end
      1493: begin  // instr 1029 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1256[a1]);
              t1 = $signed(r1255[a2]);
              t2 = (t1 < t0) ? t1 : t0;
              r1257[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 300;
        end
        state <= 1494;
      end
      1494: begin  // instr 1030 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1212[a1]);
              r1258[a0] = t0[5:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 300;
        end
        state <= 1495;
      end
      1495: begin  // instr 1031 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1258[a1]);
              t1 = $signed(r1211[a2]);
              t2 = t0 + t1;
              r1259[a0] = t2[8:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 300;
          a2 = a2 - 30;
        end
        state <= 1496;
      end
      1496: begin  // instr 1032 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom13_lit[a1]);
        t1 = t0;
        r1260[a0] = t1[9:0];
        state <= 1497;
      end
      1497: begin  // instr 1033 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1260[a1]);
              t1 = $signed(r1259[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r1261[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 300;
        end
        state <= 1498;
      end
      1498: begin  // instr 1034 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom14_lit[a1]);
        t1 = t0;
        r1262[a0] = t1[9:0];
        state <= 1499;
      end
      1499: begin  // instr 1035 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1262[a1]);
              t1 = $signed(r1261[a2]);
              t2 = (t1 < t0) ? t1 : t0;
              r1263[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 300;
        end
        state <= 1500;
      end
      1500: begin  // instr 1036 concat
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1257[a1]);
              r1264[a0] = t0[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a0 = a0 + 300;
        end
        state <= 1501;
      end
      1501: begin  // concat
        a0 = 300;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1263[a1]);
              r1264[a0] = t0[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a0 = a0 + 300;
        end
        state <= 1502;
      end
      1502: begin  // instr 1037 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(rom7_c[a1]);
          t1 = t0;
          r1265[a0] = t1[0:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1503;
      end
      1503: begin  // instr 1038 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1265[a1]);
              r1266[a0] = t0[0:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 10;
          end
        end
        state <= 1504;
      end
      1504: begin  // instr 1039 concat
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 60; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1264[a1]);
              r1267[a0] = t0[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a0 = a0 + 10;
        end
        state <= 1505;
      end
      1505: begin  // concat
        a0 = 600;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1266[a1]);
              r1267[a0] = t0[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a0 = a0 + 600;
        end
        state <= 1506;
      end
      1506: begin  // instr 1040 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 61; c2 = c2 + 1) begin
              t0 = $signed(r1267[a1]);
              r1268[a0] = t0[9:0];
              a0 = a0 + 1;
              a1 = a1 + 10;
            end
            a1 = a1 - 609;
          end
          a1 = a1 + 600;
        end
        state <= 1507;
      end
      1507: begin  // instr 1041 reduce_max
        t0 = -254;
        a0 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          r1269[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 1508;
      end
      1508: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 61; c2 = c2 + 1) begin
              t0 = $signed(r1269[a0]);
              t1 = $signed(r1268[a1]);
              t2 = (t0 < t1) ? t1 : t0;
              r1269[a0] = t2[9:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1509;
      end
      1509: begin  // instr 1042 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1269[a1]);
            t1 = $signed(rom33_lit[a2]);
            t2 = t0 - t1;
            r1270[a0] = t2[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1510;
      end
      1510: begin  // instr 1043 loop
        k31 = 0;
        state <= 1511;
      end
      1511: begin  // loop31.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 610; c0 = c0 + 1) begin
          t0 = $signed(r1268[a1]);
          r1271[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1512;
      end
      1512: begin  // loop31.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom33_lit[a1]);
          r1272[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1513;
      end
      1513: begin  // loop31.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r1273[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1514;
      end
      1514: begin  // loop31.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1270[a1]);
          r1274[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1515;
      end
      1515: begin  // loop31.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1269[a1]);
          r1275[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1516;
      end
      1516: begin  // loop31.head
        if (k31 == 11) state <= 1532;
        else state <= 1517;
      end
      1517: begin  // instr 1044 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r1273[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r1276[a0] = t2[4:0];
        state <= 1518;
      end
      1518: begin  // instr 1045 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1274[a1]);
            t1 = $signed(r1275[a2]);
            t2 = t0 + t1;
            r1277[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
        end
        state <= 1519;
      end
      1519: begin  // instr 1046 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1277[a1]);
            t1 = t0 >>> 1;
            r1278[a0] = t1[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1520;
      end
      1520: begin  // instr 1047 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r1278[a1]);
              r1279[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1521;
      end
      1521: begin  // instr 1048 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 61; c2 = c2 + 1) begin
              t0 = $signed(r1271[a1]);
              t1 = $signed(r1279[a2]);
              t2 = t0 - t1;
              r1280[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 610;
          a2 = a2 - 10;
        end
        state <= 1522;
      end
      1522: begin  // instr 1049 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 61; c2 = c2 + 1) begin
              t0 = $signed(r1280[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r1281[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 610;
        end
        state <= 1523;
      end
      1523: begin  // instr 1050 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          r1282[a0] = t0[16:0];
          a0 = a0 + 1;
        end
        state <= 1524;
      end
      1524: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 61; c2 = c2 + 1) begin
              t0 = $signed(r1282[a0]);
              t1 = $signed(r1281[a1]);
              t2 = t0 + t1;
              r1282[a0] = t2[16:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1525;
      end
      1525: begin  // instr 1051 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1282[a1]);
            t1 = $signed(r1272[a2]);
            t2 = (t0 > t1) ? 1 : 0;
            r1283[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1526;
      end
      1526: begin  // instr 1052 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = r1283[a1];
            t1 = $signed(r1274[a2]);
            t2 = $signed(r1278[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r1284[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
          a3 = a3 - 10;
        end
        state <= 1527;
      end
      1527: begin  // instr 1053 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = r1283[a1];
            t1 = $signed(r1278[a2]);
            t2 = $signed(r1275[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r1285[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
          a3 = a3 - 10;
        end
        state <= 1528;
      end
      1528: begin  // loop31.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1276[a1]);
          r1273[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1529;
      end
      1529: begin  // loop31.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1284[a1]);
          r1274[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1530;
      end
      1530: begin  // loop31.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1285[a1]);
          r1275[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1531;
      end
      1531: begin  // loop31.adv
        k31 = k31 + 1;
        state <= 1516;
      end
      1532: begin  // loop31.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1273[a1]);
          r1286[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1533;
      end
      1533: begin  // loop31.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1274[a1]);
          r1287[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1534;
      end
      1534: begin  // loop31.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1275[a1]);
          r1288[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1535;
      end
      1535: begin  // instr 1054 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r1251[a1]);
              r1289[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1536;
      end
      1536: begin  // instr 1055 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r1288[a1]);
              r1290[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1537;
      end
      1537: begin  // instr 1056 concat
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r1289[a1]);
              r1291[a0] = t0[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1538;
      end
      1538: begin  // concat
        a0 = 1;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r1290[a1]);
              r1291[a0] = t0[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1539;
      end
      1539: begin  // instr 1057 reduce_max
        t0 = -510;
        a0 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          r1292[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 1540;
      end
      1540: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 2; c2 = c2 + 1) begin
              t0 = $signed(r1292[a0]);
              t1 = $signed(r1291[a1]);
              t2 = (t0 < t1) ? t1 : t0;
              r1292[a0] = t2[9:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1541;
      end
      1541: begin  // instr 1058 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1292[a1]);
            t1 = $signed(rom34_lit[a2]);
            t2 = t0 - t1;
            r1294[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1542;
      end
      1542: begin  // instr 1059 loop
        k32 = 0;
        state <= 1543;
      end
      1543: begin  // loop32.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          t0 = $signed(r1291[a1]);
          r1295[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1544;
      end
      1544: begin  // loop32.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom34_lit[a1]);
          r1296[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1545;
      end
      1545: begin  // loop32.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r1297[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1546;
      end
      1546: begin  // loop32.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1294[a1]);
          r1298[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1547;
      end
      1547: begin  // loop32.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1292[a1]);
          r1299[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1548;
      end
      1548: begin  // loop32.head
        if (k32 == 8) state <= 1564;
        else state <= 1549;
      end
      1549: begin  // instr 1060 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r1297[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r1300[a0] = t2[4:0];
        state <= 1550;
      end
      1550: begin  // instr 1061 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1298[a1]);
            t1 = $signed(r1299[a2]);
            t2 = t0 + t1;
            r1301[a0] = t2[11:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
        end
        state <= 1551;
      end
      1551: begin  // instr 1062 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1301[a1]);
            t1 = t0 >>> 1;
            r1302[a0] = t1[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1552;
      end
      1552: begin  // instr 1063 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r1302[a1]);
              r1303[a0] = t0[10:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1553;
      end
      1553: begin  // instr 1064 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 2; c2 = c2 + 1) begin
              t0 = $signed(r1295[a1]);
              t1 = $signed(r1303[a2]);
              t2 = t0 - t1;
              r1304[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 20;
          a2 = a2 - 10;
        end
        state <= 1554;
      end
      1554: begin  // instr 1065 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 2; c2 = c2 + 1) begin
              t0 = $signed(r1304[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r1305[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 20;
        end
        state <= 1555;
      end
      1555: begin  // instr 1066 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          r1306[a0] = t0[11:0];
          a0 = a0 + 1;
        end
        state <= 1556;
      end
      1556: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 2; c2 = c2 + 1) begin
              t0 = $signed(r1306[a0]);
              t1 = $signed(r1305[a1]);
              t2 = t0 + t1;
              r1306[a0] = t2[11:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1557;
      end
      1557: begin  // instr 1067 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1306[a1]);
            t1 = $signed(r1296[a2]);
            t2 = (t0 > t1) ? 1 : 0;
            r1307[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1558;
      end
      1558: begin  // instr 1068 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = r1307[a1];
            t1 = $signed(r1298[a2]);
            t2 = $signed(r1302[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r1308[a0] = t3[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
          a3 = a3 - 10;
        end
        state <= 1559;
      end
      1559: begin  // instr 1069 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = r1307[a1];
            t1 = $signed(r1302[a2]);
            t2 = $signed(r1299[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r1309[a0] = t3[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
          a3 = a3 - 10;
        end
        state <= 1560;
      end
      1560: begin  // loop32.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1300[a1]);
          r1297[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1561;
      end
      1561: begin  // loop32.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1308[a1]);
          r1298[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1562;
      end
      1562: begin  // loop32.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1309[a1]);
          r1299[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1563;
      end
      1563: begin  // loop32.adv
        k32 = k32 + 1;
        state <= 1548;
      end
      1564: begin  // loop32.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1297[a1]);
          r1310[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1565;
      end
      1565: begin  // loop32.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1298[a1]);
          r1311[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1566;
      end
      1566: begin  // loop32.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1299[a1]);
          r1312[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1567;
      end
      1567: begin  // instr 1070 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1251[a1]);
            t1 = $signed(r1312[a2]);
            t2 = t0 - t1;
            r1313[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
        end
        state <= 1568;
      end
      1568: begin  // instr 1071 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1313[a1]);
            t1 = $signed(rom9_lit[a2]);
            t2 = (t0 < t1) ? t1 : t0;
            r1314[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1569;
      end
      1569: begin  // instr 1072 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1288[a1]);
            t1 = $signed(r1312[a2]);
            t2 = t0 - t1;
            r1315[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
        end
        state <= 1570;
      end
      1570: begin  // instr 1073 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1315[a1]);
            t1 = $signed(rom9_lit[a2]);
            t2 = (t0 < t1) ? t1 : t0;
            r1316[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1571;
      end
      1571: begin  // instr 1074 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1314[a1]);
            t1 = $signed(r1316[a2]);
            t2 = t0 - t1;
            r1317[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
        end
        state <= 1572;
      end
      1572: begin done <= 1; end
      default: state <= 0;
      endcase
    end
  end
endmodule

module oneshot_q_top(input wire clk, input wire rst, input wire start, output wire done);
  oneshot_q u_core(.clk(clk), .rst(rst), .start(start), .done(done));
endmodule
